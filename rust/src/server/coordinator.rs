//! The coordinator: admission + continuous-batching decode loop.
//!
//! One scheduler thread owns the active set. Router threads (HTTP or
//! in-process callers) enqueue requests and block on a per-request channel;
//! the scheduler admits between decode steps, prefalls new sequences,
//! steps the batch, and completes finished sequences.

use crate::model::sampler::Sampling;
use crate::server::batcher::{Batcher, BatcherCfg};
use crate::server::engine::{Engine, SeqState};
use crate::server::metrics::Metrics;
use crate::server::request::{GenRequest, GenResponse};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Coordinator configuration.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorCfg {
    pub batcher: BatcherCfg,
}

struct SchedState {
    batcher: Batcher,
    waiters: HashMap<u64, Sender<GenResponse>>,
}

/// The serving coordinator. Cloneable handle via Arc.
pub struct Coordinator {
    engine: Arc<Engine>,
    state: Mutex<SchedState>,
    wake: Condvar,
    pub metrics: Mutex<Metrics>,
    next_id: AtomicU64,
    shutdown: AtomicBool,
}

impl Coordinator {
    pub fn new(engine: Arc<Engine>, cfg: CoordinatorCfg) -> Arc<Self> {
        Arc::new(Self {
            engine,
            state: Mutex::new(SchedState {
                batcher: Batcher::new(cfg.batcher),
                waiters: HashMap::new(),
            }),
            wake: Condvar::new(),
            metrics: Mutex::new(Metrics::new()),
            next_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
        })
    }

    /// Submit a request; returns a receiver for the completion, or Err on
    /// backpressure.
    pub fn submit(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
    ) -> anyhow::Result<std::sync::mpsc::Receiver<GenResponse>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = GenRequest::new(id, prompt, max_new);
        req.sampling = sampling;
        let (tx, rx) = channel();
        {
            let mut st = self.state.lock().unwrap();
            if st.batcher.enqueue(req).is_err() {
                self.metrics.lock().unwrap().requests_rejected += 1;
                anyhow::bail!("queue full");
            }
            st.waiters.insert(id, tx);
        }
        self.wake.notify_all();
        Ok(rx)
    }

    /// Submit and wait for completion.
    pub fn submit_blocking(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
    ) -> anyhow::Result<GenResponse> {
        let rx = self.submit(prompt, max_new, sampling)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("scheduler dropped request"))
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// The scheduler loop. Run on a dedicated thread:
    /// `std::thread::spawn(move || coordinator.run_scheduler())`.
    pub fn run_scheduler(self: &Arc<Self>) {
        // (request, seq, admitted_at) triples in flight.
        let mut active: Vec<(GenRequest, SeqState, Instant)> = Vec::new();
        loop {
            if self.is_shutdown() {
                return;
            }
            // Admit new work.
            let admitted: Vec<GenRequest> = {
                let mut st = self.state.lock().unwrap();
                if active.is_empty() && st.batcher.queue_len() == 0 {
                    // Idle: wait for a submit or shutdown.
                    let st2 = self
                        .wake
                        .wait_timeout(st, std::time::Duration::from_millis(50))
                        .unwrap()
                        .0;
                    st2.batcher.queue_len(); // keep borrowck simple
                    continue;
                }
                st.batcher.admit(active.len())
            };
            for req in admitted {
                let queue_ms = req.arrived.elapsed().as_secs_f64() * 1e3;
                let mut seq =
                    self.engine
                        .admit(req.id, &req.prompt, req.max_new, req.sampling);
                self.engine.prefill(&mut seq);
                {
                    let mut m = self.metrics.lock().unwrap();
                    m.queue_ms.add(queue_ms);
                    m.tokens_prefilled += seq.prompt_tokens.len() as u64;
                }
                active.push((req, seq, Instant::now()));
            }
            if active.is_empty() {
                continue;
            }
            // One decode step across the batch: only unfinished sequences
            // enter (chunks stay balanced when completions cluster); the
            // decode policy itself is shared with `Engine::step_batch`.
            let t0 = Instant::now();
            let stepped = {
                let mut seqs: Vec<&mut SeqState> = active
                    .iter_mut()
                    .map(|(_, s, _)| s)
                    .filter(|s| !s.finished())
                    .collect();
                let n = seqs.len();
                self.engine.step_slots(&mut seqs[..]);
                n
            };
            let step_ms = t0.elapsed().as_secs_f64() * 1e3;
            {
                let mut m = self.metrics.lock().unwrap();
                m.per_token_ms.add(step_ms / stepped.max(1) as f64);
            }
            // Complete finished sequences.
            let mut i = 0;
            while i < active.len() {
                if active[i].1.finished() {
                    let (req, seq, started) = active.swap_remove(i);
                    let total_ms = req.arrived.elapsed().as_secs_f64() * 1e3;
                    let resp = GenResponse {
                        id: req.id,
                        text: seq.text(),
                        n_prompt_tokens: seq.prompt_tokens.len(),
                        n_generated: seq.generated.len(),
                        queue_ms: (started - req.arrived).as_secs_f64() * 1e3,
                        total_ms,
                        density: seq.stats.density(),
                    };
                    {
                        let mut m = self.metrics.lock().unwrap();
                        m.requests_total += 1;
                        m.tokens_generated += seq.generated.len() as u64;
                        m.total_ms.add(total_ms);
                        m.macs_kept += seq.stats.macs_kept + seq.stats.macs_extra;
                        m.macs_dense += seq.stats.macs_dense;
                    }
                    let tx = self.state.lock().unwrap().waiters.remove(&req.id);
                    if let Some(tx) = tx {
                        let _ = tx.send(resp);
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::Model;
    use crate::model::ModelConfig;
    use crate::server::engine::EngineCfg;
    use crate::sparsity::Dense;

    fn start_coordinator(max_batch: usize) -> (Arc<Coordinator>, std::thread::JoinHandle<()>) {
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 91));
        let engine = Arc::new(Engine::new(
            model,
            Arc::new(Dense),
            EngineCfg {
                threads: 2,
                ..EngineCfg::default()
            },
        ));
        let coord = Coordinator::new(
            engine,
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch,
                    max_queue: 32,
                },
            },
        );
        let c2 = Arc::clone(&coord);
        let handle = std::thread::spawn(move || c2.run_scheduler());
        (coord, handle)
    }

    #[test]
    fn single_request_completes() {
        let (coord, handle) = start_coordinator(4);
        let resp = coord.submit_blocking("12+34=", 5, Sampling::Greedy).unwrap();
        assert_eq!(resp.n_generated, 5);
        assert_eq!(resp.text.len(), 5);
        assert!(resp.total_ms >= 0.0);
        coord.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn concurrent_requests_all_complete_and_match_sequential() {
        let (coord, handle) = start_coordinator(3);
        // Sequential references using a fresh engine.
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 91));
        let engine = Engine::new(model, Arc::new(Dense), EngineCfg::default());
        let prompts = ["abc", "hello w", "1+2=", "xyzw", "the sun"];
        let expected: Vec<String> = prompts
            .iter()
            .map(|p| engine.run_to_completion(p, 6, Sampling::Greedy).0)
            .collect();
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| coord.submit(p, 6, Sampling::Greedy).unwrap())
            .collect();
        for (rx, exp) in rxs.into_iter().zip(&expected) {
            let resp = rx.recv().unwrap();
            assert_eq!(&resp.text, exp, "batched text diverged");
        }
        let m = coord.metrics.lock().unwrap();
        assert_eq!(m.requests_total, 5);
        assert_eq!(m.tokens_generated, 30);
        drop(m);
        coord.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn backpressure_rejects() {
        // Tiny queue: flood and expect at least one rejection.
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 92));
        let engine = Arc::new(Engine::dense(model, EngineCfg::default()));
        let coord = Coordinator::new(
            engine,
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch: 1,
                    max_queue: 2,
                },
            },
        );
        // No scheduler running -> queue fills up.
        assert!(coord.submit("a", 1, Sampling::Greedy).is_ok());
        assert!(coord.submit("b", 1, Sampling::Greedy).is_ok());
        assert!(coord.submit("c", 1, Sampling::Greedy).is_err());
        assert_eq!(coord.metrics.lock().unwrap().requests_rejected, 1);
    }
}
