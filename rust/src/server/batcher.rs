//! Continuous batcher: FIFO admission with a bounded active set.
//!
//! New sequences are admitted between decode steps whenever a slot frees up
//! (the Orca/vLLM iteration-level scheduling discipline), with backpressure
//! via a bounded waiting queue.

use crate::server::request::GenRequest;
use std::collections::VecDeque;

/// Batcher configuration.
#[derive(Clone, Debug)]
pub struct BatcherCfg {
    /// Max concurrent sequences in the decode batch.
    pub max_batch: usize,
    /// Max queued (unadmitted) requests before the router returns 503.
    pub max_queue: usize,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_queue: 256,
        }
    }
}

/// FIFO queue with explicit capacity; thread-safety is provided by the
/// coordinator's mutex around the whole scheduling state.
pub struct Batcher {
    cfg: BatcherCfg,
    waiting: VecDeque<GenRequest>,
}

impl Batcher {
    pub fn new(cfg: BatcherCfg) -> Self {
        Self {
            cfg,
            waiting: VecDeque::new(),
        }
    }

    /// Try to enqueue; Err = backpressure (queue full).
    pub fn enqueue(&mut self, req: GenRequest) -> Result<(), GenRequest> {
        if self.waiting.len() >= self.cfg.max_queue {
            return Err(req);
        }
        self.waiting.push_back(req);
        Ok(())
    }

    /// Admit as many waiting requests as fit given `active` running
    /// sequences. Returns the admitted requests, FIFO order.
    pub fn admit(&mut self, active: usize) -> Vec<GenRequest> {
        let slots = self.cfg.max_batch.saturating_sub(active);
        let take = slots.min(self.waiting.len());
        self.waiting.drain(..take).collect()
    }

    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn max_batch(&self) -> usize {
        self.cfg.max_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> GenRequest {
        GenRequest::new(id, "p", 4)
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 2,
            max_queue: 10,
        });
        for i in 0..5 {
            b.enqueue(req(i)).unwrap();
        }
        let first = b.admit(0);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let second = b.admit(1); // one active slot occupied
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert_eq!(b.queue_len(), 2);
    }

    #[test]
    fn backpressure() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 1,
            max_queue: 2,
        });
        assert!(b.enqueue(req(0)).is_ok());
        assert!(b.enqueue(req(1)).is_ok());
        let rejected = b.enqueue(req(2));
        assert!(rejected.is_err());
        assert_eq!(rejected.unwrap_err().id, 2);
    }

    #[test]
    fn no_admission_when_full() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 4,
            max_queue: 10,
        });
        b.enqueue(req(0)).unwrap();
        assert!(b.admit(4).is_empty());
        assert_eq!(b.queue_len(), 1);
    }

    #[test]
    fn admit_never_exceeds_batch() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 3,
            max_queue: 100,
        });
        for i in 0..50 {
            b.enqueue(req(i)).unwrap();
        }
        for active in 0..=3 {
            let admitted = b.admit(active);
            assert!(admitted.len() + active <= 3);
        }
    }
}
