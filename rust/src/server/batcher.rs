//! Continuous batcher: FIFO admission with a bounded active set.
//!
//! New sequences are admitted between decode steps whenever a slot frees up
//! (the Orca/vLLM iteration-level scheduling discipline), with backpressure
//! via a bounded waiting queue.

use crate::server::request::GenRequest;
use std::collections::VecDeque;

/// Batcher configuration.
#[derive(Clone, Debug)]
pub struct BatcherCfg {
    /// Max concurrent sequences in the decode batch.
    pub max_batch: usize,
    /// Max queued (unadmitted) requests before the router returns 503.
    pub max_queue: usize,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_queue: 256,
        }
    }
}

/// FIFO queue with explicit capacity; thread-safety is provided by the
/// coordinator's mutex around the whole scheduling state.
pub struct Batcher {
    cfg: BatcherCfg,
    waiting: VecDeque<GenRequest>,
}

impl Batcher {
    pub fn new(cfg: BatcherCfg) -> Self {
        Self {
            cfg,
            waiting: VecDeque::new(),
        }
    }

    /// Try to enqueue; Err = backpressure (queue full).
    pub fn enqueue(&mut self, req: GenRequest) -> Result<(), GenRequest> {
        if self.waiting.len() >= self.cfg.max_queue {
            return Err(req);
        }
        self.waiting.push_back(req);
        Ok(())
    }

    /// Admit as many waiting requests as fit given `active` running
    /// sequences. Returns the admitted requests, FIFO order.
    pub fn admit(&mut self, active: usize) -> Vec<GenRequest> {
        self.admit_with(active, |_| true)
    }

    /// Block-aware admission: admit FIFO while batch slots remain and
    /// `fits` approves the queue head. The head blocks the line when it
    /// doesn't fit (no skip-ahead), preserving FIFO fairness.
    pub fn admit_with(
        &mut self,
        active: usize,
        mut fits: impl FnMut(&GenRequest) -> bool,
    ) -> Vec<GenRequest> {
        let mut slots = self.cfg.max_batch.saturating_sub(active);
        let mut out = Vec::new();
        while slots > 0 {
            match self.waiting.front() {
                Some(head) if fits(head) => {
                    out.push(self.waiting.pop_front().expect("head exists"));
                    slots -= 1;
                }
                _ => break,
            }
        }
        out
    }

    /// Put a preempted request back at the head of the line. Bypasses the
    /// queue capacity: preemption must never drop accepted work.
    pub fn requeue_front(&mut self, req: GenRequest) {
        self.waiting.push_front(req);
    }

    /// Forced admission of the queue head (progress guarantee when nothing
    /// is active and the head's worst case exceeds the pool).
    pub fn pop_front(&mut self) -> Option<GenRequest> {
        self.waiting.pop_front()
    }

    /// Drop a still-queued request by id (client cancellation before
    /// admission). Returns whether anything was removed.
    pub fn remove(&mut self, id: u64) -> bool {
        let before = self.waiting.len();
        self.waiting.retain(|r| r.id != id);
        before != self.waiting.len()
    }

    /// Remove and return every queued request matching `pred` (deadline
    /// expiry sweeps). Queue order of the survivors is preserved.
    pub fn expire(&mut self, mut pred: impl FnMut(&GenRequest) -> bool) -> Vec<GenRequest> {
        let mut expired = Vec::new();
        let mut keep = VecDeque::with_capacity(self.waiting.len());
        for req in self.waiting.drain(..) {
            if pred(&req) {
                expired.push(req);
            } else {
                keep.push_back(req);
            }
        }
        self.waiting = keep;
        expired
    }

    /// Remove and return the whole queue (graceful drain: queued work is
    /// shed with a terminal response instead of silently dropped).
    pub fn drain_queue(&mut self) -> Vec<GenRequest> {
        self.waiting.drain(..).collect()
    }

    /// Ids of every queued request (supervisor restarts use this to tell
    /// still-queued survivors from orphaned in-flight work).
    pub fn queued_ids(&self) -> Vec<u64> {
        self.waiting.iter().map(|r| r.id).collect()
    }

    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn max_batch(&self) -> usize {
        self.cfg.max_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> GenRequest {
        GenRequest::new(id, "p", 4)
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 2,
            max_queue: 10,
        });
        for i in 0..5 {
            b.enqueue(req(i)).unwrap();
        }
        let first = b.admit(0);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let second = b.admit(1); // one active slot occupied
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert_eq!(b.queue_len(), 2);
    }

    #[test]
    fn backpressure() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 1,
            max_queue: 2,
        });
        assert!(b.enqueue(req(0)).is_ok());
        assert!(b.enqueue(req(1)).is_ok());
        let rejected = b.enqueue(req(2));
        assert!(rejected.is_err());
        assert_eq!(rejected.unwrap_err().id, 2);
    }

    #[test]
    fn no_admission_when_full() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 4,
            max_queue: 10,
        });
        b.enqueue(req(0)).unwrap();
        assert!(b.admit(4).is_empty());
        assert_eq!(b.queue_len(), 1);
    }

    #[test]
    fn admit_with_blocks_on_unfitting_head() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 4,
            max_queue: 10,
        });
        for i in 0..4 {
            b.enqueue(req(i)).unwrap();
        }
        // Head (id 0) fits, id 1 does not: admission stops at the head of
        // line even though id 2 would fit.
        let admitted = b.admit_with(0, |r| r.id != 1);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        assert_eq!(b.queue_len(), 3);
    }

    #[test]
    fn requeue_front_goes_first() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 2,
            max_queue: 2,
        });
        b.enqueue(req(1)).unwrap();
        b.enqueue(req(2)).unwrap();
        // Preempted request jumps the (full) queue.
        b.requeue_front(req(7));
        assert_eq!(b.queue_len(), 3);
        let admitted = b.admit(0);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![7, 1]);
        assert_eq!(b.pop_front().unwrap().id, 2);
        assert!(b.pop_front().is_none());
    }

    #[test]
    fn remove_drops_queued_request() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 2,
            max_queue: 10,
        });
        for i in 0..3 {
            b.enqueue(req(i)).unwrap();
        }
        assert!(b.remove(1));
        assert!(!b.remove(1), "already gone");
        let admitted = b.admit(0);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn expire_partitions_and_preserves_order() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 2,
            max_queue: 10,
        });
        for i in 0..5 {
            b.enqueue(req(i)).unwrap();
        }
        let expired = b.expire(|r| r.id % 2 == 0);
        assert_eq!(expired.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        assert_eq!(b.queued_ids(), vec![1, 3]);
        let drained = b.drain_queue();
        assert_eq!(drained.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.queue_len(), 0);
    }

    #[test]
    fn admit_never_exceeds_batch() {
        let mut b = Batcher::new(BatcherCfg {
            max_batch: 3,
            max_queue: 100,
        });
        for i in 0..50 {
            b.enqueue(req(i)).unwrap();
        }
        for active in 0..=3 {
            let admitted = b.admit(active);
            assert!(admitted.len() + active <= 3);
        }
    }
}
