//! Serving metrics: throughput, latency percentiles, achieved density.

use crate::obs::{Hist, PromText, RateWindow};
use crate::util::json::Json;
use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::time::Instant;

/// Aggregated serving metrics; the coordinator holds this behind its lock.
pub struct Metrics {
    started: Instant,
    pub requests_total: u64,
    pub requests_rejected: u64,
    pub tokens_generated: u64,
    pub tokens_prefilled: u64,
    pub queue_ms: Summary,
    pub total_ms: Summary,
    pub per_token_ms: Summary,
    /// Wall time between consecutive decode steps of the scheduler — the
    /// inter-token latency a decoding sequence observes, including any
    /// prefill chunk interleaved between the two steps. The p95 is the
    /// fairness headline: it stays bounded by the per-iteration prefill
    /// token budget regardless of co-running prompt lengths.
    pub decode_gap_ms: Summary,
    pub macs_kept: u64,
    pub macs_dense: u64,
    /// Prefill chunks run by the scheduler (several per long prompt).
    pub prefill_chunks_total: u64,
    /// Sequences preempted and requeued for KV pool pressure.
    pub preemptions_total: u64,
    /// Streaming sequences cancelled because the client disconnected
    /// mid-generation (their remaining decode work and KV blocks freed).
    pub cancellations_total: u64,
    /// Paged-KV pool gauges (updated by the coordinator at report time;
    /// stay 0 for flat-cache engines).
    pub blocks_total: u64,
    pub blocks_in_use: u64,
    /// Prompt tokens served from / missed by the prefix cache.
    pub prefix_hit_tokens: u64,
    pub prefix_miss_tokens: u64,
    /// Speculative-decoding rounds (draft pass + verify chunk) completed.
    pub spec_rounds_total: u64,
    /// Draft tokens proposed beyond each round's free first token.
    pub spec_drafted_tokens: u64,
    /// Of those, accepted by the production verify pass.
    pub spec_accepted_tokens: u64,
    /// Deployed weight representation (`f32`, `int8`, `int4`) and its
    /// resident/dense-equivalent byte footprint (refreshed at report time).
    pub weight_repr: String,
    pub weight_bytes_resident: u64,
    pub weight_bytes_dense: u64,
    /// Per-sequence panics caught and converted to `internal_error`
    /// completions (isolation working as intended: one request degraded,
    /// not the process).
    pub panics_caught_total: u64,
    /// Scheduler iterations that panicked outside per-sequence isolation
    /// and were restarted by the supervisor.
    pub scheduler_restarts_total: u64,
    /// Requests terminated for blowing their deadline (queued or active).
    pub deadline_exceeded_total: u64,
    /// Requests shed under overload or drain (503 + Retry-After).
    pub shed_total: u64,
    /// Waiting (unadmitted) requests right now (refreshed at report time).
    pub queue_depth: u64,
    /// Wall time of the last completed graceful drain (0 until one runs).
    pub drain_duration_ms: f64,
    /// Prometheus-renderable latency histograms alongside the `Summary`
    /// percentile windows (fixed log-spaced buckets aggregate across
    /// scrapes; percentiles don't). Fed by the `observe_*` helpers.
    pub queue_ms_hist: Hist,
    pub total_ms_hist: Hist,
    pub per_token_ms_hist: Hist,
    pub decode_gap_ms_hist: Hist,
    /// Terminal outcomes by finish reason (`length`, `cache_full`,
    /// `deadline_exceeded`, `shed`, `shutdown`, ...). Counts every terminal
    /// event — completions and never-ran terminals alike.
    pub finished: BTreeMap<String, u64>,
    /// Tokens committed by decode, bucketed per second for the sliding-
    /// window throughput (the lifetime average decays toward zero on an
    /// idle server; this doesn't).
    pub decode_window: RateWindow,
    /// SLO feed counters: cumulative (events, breaches) pairs that the
    /// burn-rate engine ([`crate::obs::SloEngine`]) differences into its
    /// per-second windows. The breach thresholds come from
    /// `CoordinatorCfg::slos` and are applied at the observe sites.
    pub latency_events_total: u64,
    pub latency_breaches_total: u64,
    pub decode_gap_events_total: u64,
    pub decode_gap_breaches_total: u64,
}

/// Build metadata baked in at compile time (`wisparse_build_info`). The
/// git SHA and feature list arrive via `WISPARSE_GIT_SHA` /
/// `WISPARSE_FEATURES` set at build time; absent (local builds) they read
/// `"unknown"` / `"default"`.
pub fn build_info() -> (&'static str, &'static str, &'static str) {
    (
        env!("CARGO_PKG_VERSION"),
        option_env!("WISPARSE_GIT_SHA").unwrap_or("unknown"),
        option_env!("WISPARSE_FEATURES").unwrap_or("default"),
    )
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            requests_total: 0,
            requests_rejected: 0,
            tokens_generated: 0,
            tokens_prefilled: 0,
            queue_ms: Summary::new(1024),
            total_ms: Summary::new(1024),
            per_token_ms: Summary::new(4096),
            decode_gap_ms: Summary::new(4096),
            macs_kept: 0,
            macs_dense: 0,
            prefill_chunks_total: 0,
            preemptions_total: 0,
            cancellations_total: 0,
            blocks_total: 0,
            blocks_in_use: 0,
            prefix_hit_tokens: 0,
            prefix_miss_tokens: 0,
            spec_rounds_total: 0,
            spec_drafted_tokens: 0,
            spec_accepted_tokens: 0,
            weight_repr: "f32".to_string(),
            weight_bytes_resident: 0,
            weight_bytes_dense: 0,
            panics_caught_total: 0,
            scheduler_restarts_total: 0,
            deadline_exceeded_total: 0,
            shed_total: 0,
            queue_depth: 0,
            drain_duration_ms: 0.0,
            queue_ms_hist: Hist::new_ms(),
            total_ms_hist: Hist::new_ms(),
            per_token_ms_hist: Hist::new_ms(),
            decode_gap_ms_hist: Hist::new_ms(),
            finished: BTreeMap::new(),
            decode_window: RateWindow::new(),
            latency_events_total: 0,
            latency_breaches_total: 0,
            decode_gap_events_total: 0,
            decode_gap_breaches_total: 0,
        }
    }

    /// Fold another replica's metrics into this one. The router builds its
    /// unified `/metrics` aggregate by merging every replica into a fresh
    /// `Metrics` at scrape time: counters and the finish-reason map sum,
    /// histograms merge bucket-wise, summary windows blend (bounded), and
    /// the throughput window re-bases onto the earliest epoch so the
    /// aggregate windowed rate is the sum of replica rates. Weight gauges
    /// are overwritten, not summed — replicas share one `Arc<Model>`, so
    /// resident bytes must be counted once.
    pub fn merge_from(&mut self, o: &Metrics) {
        if o.started < self.started {
            self.started = o.started;
        }
        self.requests_total += o.requests_total;
        self.requests_rejected += o.requests_rejected;
        self.tokens_generated += o.tokens_generated;
        self.tokens_prefilled += o.tokens_prefilled;
        self.queue_ms.merge_from(&o.queue_ms);
        self.total_ms.merge_from(&o.total_ms);
        self.per_token_ms.merge_from(&o.per_token_ms);
        self.decode_gap_ms.merge_from(&o.decode_gap_ms);
        self.macs_kept += o.macs_kept;
        self.macs_dense += o.macs_dense;
        self.prefill_chunks_total += o.prefill_chunks_total;
        self.preemptions_total += o.preemptions_total;
        self.cancellations_total += o.cancellations_total;
        self.blocks_total += o.blocks_total;
        self.blocks_in_use += o.blocks_in_use;
        self.prefix_hit_tokens += o.prefix_hit_tokens;
        self.prefix_miss_tokens += o.prefix_miss_tokens;
        self.spec_rounds_total += o.spec_rounds_total;
        self.spec_drafted_tokens += o.spec_drafted_tokens;
        self.spec_accepted_tokens += o.spec_accepted_tokens;
        self.weight_repr = o.weight_repr.clone();
        self.weight_bytes_resident = o.weight_bytes_resident;
        self.weight_bytes_dense = o.weight_bytes_dense;
        self.panics_caught_total += o.panics_caught_total;
        self.scheduler_restarts_total += o.scheduler_restarts_total;
        self.deadline_exceeded_total += o.deadline_exceeded_total;
        self.shed_total += o.shed_total;
        self.queue_depth += o.queue_depth;
        self.drain_duration_ms = self.drain_duration_ms.max(o.drain_duration_ms);
        self.queue_ms_hist.merge_from(&o.queue_ms_hist);
        self.total_ms_hist.merge_from(&o.total_ms_hist);
        self.per_token_ms_hist.merge_from(&o.per_token_ms_hist);
        self.decode_gap_ms_hist.merge_from(&o.decode_gap_ms_hist);
        for (reason, n) in &o.finished {
            *self.finished.entry(reason.clone()).or_insert(0) += n;
        }
        self.decode_window.merge_from(&o.decode_window);
        self.latency_events_total += o.latency_events_total;
        self.latency_breaches_total += o.latency_breaches_total;
        self.decode_gap_events_total += o.decode_gap_events_total;
        self.decode_gap_breaches_total += o.decode_gap_breaches_total;
    }

    /// Terminal events counted so far (the error-rate SLO's denominator).
    pub fn finished_events(&self) -> u64 {
        self.finished.values().sum()
    }

    /// Terminal events that were `internal_error` (the error-rate SLO's
    /// numerator).
    pub fn internal_errors(&self) -> u64 {
        self.finished.get("internal_error").copied().unwrap_or(0)
    }

    /// Record one request's queue wait (summary window + histogram).
    pub fn observe_queue(&mut self, ms: f64) {
        self.queue_ms.add(ms);
        self.queue_ms_hist.observe(ms);
    }

    /// Record one request's end-to-end latency.
    pub fn observe_total(&mut self, ms: f64) {
        self.total_ms.add(ms);
        self.total_ms_hist.observe(ms);
    }

    /// Record one decode step's per-committed-token latency.
    pub fn observe_per_token(&mut self, ms: f64) {
        self.per_token_ms.add(ms);
        self.per_token_ms_hist.observe(ms);
    }

    /// Record one completion-to-completion decode gap.
    pub fn observe_decode_gap(&mut self, ms: f64) {
        self.decode_gap_ms.add(ms);
        self.decode_gap_ms_hist.observe(ms);
    }

    /// Count one terminal event under its finish reason.
    pub fn count_finish(&mut self, reason: &str) {
        *self.finished.entry(reason.to_string()).or_insert(0) += 1;
    }

    /// Feed `n` freshly committed tokens into the sliding throughput window.
    pub fn record_decoded(&mut self, n: u64) {
        self.decode_window.add(n);
    }

    /// Decode throughput over the trailing 30s window (tokens/s). Unlike
    /// [`Metrics::throughput`] this reads 0 on an idle server instead of a
    /// slowly decaying lifetime average.
    pub fn throughput_window(&self) -> f64 {
        self.decode_window.rate()
    }

    /// Dense-f32 bytes over resident bytes (1.0 for unquantized weights or
    /// before the gauges are populated).
    pub fn quant_compression_ratio(&self) -> f64 {
        if self.weight_bytes_resident == 0 {
            return 1.0;
        }
        self.weight_bytes_dense as f64 / self.weight_bytes_resident as f64
    }

    /// Fraction of proposed draft tokens accepted by verification (0.0
    /// before any speculative round has run).
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_drafted_tokens == 0 {
            return 0.0;
        }
        self.spec_accepted_tokens as f64 / self.spec_drafted_tokens as f64
    }

    /// Fraction of prompt tokens served from the prefix cache (0.0 before
    /// any prompt has been seen).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hit_tokens + self.prefix_miss_tokens;
        if total == 0 {
            return 0.0;
        }
        self.prefix_hit_tokens as f64 / total as f64
    }

    /// Decode throughput over the server's lifetime (tokens/s).
    pub fn throughput(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / secs
    }

    pub fn density(&self) -> f64 {
        if self.macs_dense == 0 {
            return 1.0;
        }
        self.macs_kept as f64 / self.macs_dense as f64
    }

    pub fn to_json(&self) -> Json {
        let (version, git_sha, features) = build_info();
        Json::obj(vec![
            (
                "build_info",
                Json::obj(vec![
                    ("version", Json::Str(version.to_string())),
                    ("git_sha", Json::Str(git_sha.to_string())),
                    ("features", Json::Str(features.to_string())),
                ]),
            ),
            ("uptime_s", Json::Num(self.started.elapsed().as_secs_f64())),
            ("requests_total", Json::Num(self.requests_total as f64)),
            ("requests_rejected", Json::Num(self.requests_rejected as f64)),
            ("tokens_generated", Json::Num(self.tokens_generated as f64)),
            ("tokens_prefilled", Json::Num(self.tokens_prefilled as f64)),
            ("throughput_tok_s", Json::Num(self.throughput())),
            (
                "throughput_window_tok_s",
                Json::Num(self.throughput_window()),
            ),
            ("density", Json::Num(self.density())),
            ("queue_ms_p50", Json::Num(self.queue_ms.percentile(0.5))),
            ("queue_ms_p99", Json::Num(self.queue_ms.percentile(0.99))),
            ("total_ms_p50", Json::Num(self.total_ms.percentile(0.5))),
            ("total_ms_p99", Json::Num(self.total_ms.percentile(0.99))),
            (
                "per_token_ms_p50",
                Json::Num(self.per_token_ms.percentile(0.5)),
            ),
            (
                "decode_gap_ms_p50",
                Json::Num(self.decode_gap_ms.percentile(0.5)),
            ),
            (
                "decode_gap_ms_p95",
                Json::Num(self.decode_gap_ms.percentile(0.95)),
            ),
            (
                "prefill_chunks_total",
                Json::Num(self.prefill_chunks_total as f64),
            ),
            (
                "cancellations_total",
                Json::Num(self.cancellations_total as f64),
            ),
            ("blocks_total", Json::Num(self.blocks_total as f64)),
            ("blocks_in_use", Json::Num(self.blocks_in_use as f64)),
            (
                "prefix_hit_tokens",
                Json::Num(self.prefix_hit_tokens as f64),
            ),
            (
                "prefix_miss_tokens",
                Json::Num(self.prefix_miss_tokens as f64),
            ),
            ("prefix_hit_rate", Json::Num(self.prefix_hit_rate())),
            (
                "preemptions_total",
                Json::Num(self.preemptions_total as f64),
            ),
            (
                "spec_rounds_total",
                Json::Num(self.spec_rounds_total as f64),
            ),
            (
                "spec_drafted_tokens",
                Json::Num(self.spec_drafted_tokens as f64),
            ),
            (
                "spec_accepted_tokens",
                Json::Num(self.spec_accepted_tokens as f64),
            ),
            (
                "spec_acceptance_rate",
                Json::Num(self.spec_acceptance_rate()),
            ),
            (
                "panics_caught_total",
                Json::Num(self.panics_caught_total as f64),
            ),
            (
                "scheduler_restarts_total",
                Json::Num(self.scheduler_restarts_total as f64),
            ),
            (
                "deadline_exceeded_total",
                Json::Num(self.deadline_exceeded_total as f64),
            ),
            ("shed_total", Json::Num(self.shed_total as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("drain_duration_ms", Json::Num(self.drain_duration_ms)),
            ("weight_repr", Json::Str(self.weight_repr.clone())),
            (
                "weight_bytes_resident",
                Json::Num(self.weight_bytes_resident as f64),
            ),
            (
                "quant_compression_ratio",
                Json::Num(self.quant_compression_ratio()),
            ),
            ("decode_tok_s", self.decode_tok_s_json()),
            ("finished_total", self.finished_json()),
        ])
    }

    fn finished_json(&self) -> Json {
        Json::obj(
            self.finished
                .iter()
                .map(|(k, v)| (k.as_str(), Json::Num(*v as f64)))
                .collect(),
        )
    }

    /// Per-representation decode throughput gauges: the server's deployed
    /// representation carries the live windowed tok/s, the others read 0.
    /// Windowed, not lifetime: a gauge that decays toward zero while the
    /// server sits idle (and dilutes bursts with idle time) is useless for
    /// alerting — the 30s window reflects what decode is doing *now*.
    fn decode_tok_s_json(&self) -> Json {
        let tput = self.throughput_window();
        Json::obj(
            ["f32", "int8", "int4"]
                .into_iter()
                .map(|r| {
                    (
                        r,
                        Json::Num(if r == self.weight_repr { tput } else { 0.0 }),
                    )
                })
                .collect(),
        )
    }

    /// Render every metric family into a Prometheus exposition builder.
    /// The coordinator appends per-block telemetry to the same builder, so
    /// `# TYPE` dedup spans the whole page.
    pub fn render_prometheus(&self, p: &mut PromText) {
        let repr = self.weight_repr.as_str();
        let (version, git_sha, features) = build_info();
        p.gauge(
            "wisparse_build_info",
            "Build metadata carried in labels; the value is always 1.",
            &[
                ("version", version),
                ("git_sha", git_sha),
                ("features", features),
            ],
            1.0,
        );
        p.gauge(
            "wisparse_uptime_seconds",
            "Seconds since server start.",
            &[],
            self.started.elapsed().as_secs_f64(),
        );
        p.counter(
            "wisparse_requests_total",
            "Requests completed.",
            &[],
            self.requests_total as f64,
        );
        p.counter(
            "wisparse_requests_rejected_total",
            "Requests refused at admission (queue full).",
            &[],
            self.requests_rejected as f64,
        );
        p.counter(
            "wisparse_tokens_generated_total",
            "Tokens committed by decode.",
            &[],
            self.tokens_generated as f64,
        );
        p.counter(
            "wisparse_tokens_prefilled_total",
            "Prompt tokens forwarded by prefill chunks.",
            &[],
            self.tokens_prefilled as f64,
        );
        p.gauge(
            "wisparse_throughput_tok_s",
            "Lifetime-average decode throughput.",
            &[],
            self.throughput(),
        );
        p.gauge(
            "wisparse_throughput_window_tok_s",
            "Decode throughput over the trailing 30s window.",
            &[],
            self.throughput_window(),
        );
        for r in ["f32", "int8", "int4"] {
            let v = if r == repr {
                self.throughput_window()
            } else {
                0.0
            };
            p.gauge(
                "wisparse_decode_tok_s",
                "Windowed decode throughput per weight representation.",
                &[("repr", r)],
                v,
            );
        }
        p.gauge(
            "wisparse_density",
            "Achieved activation density over all linear projections.",
            &[],
            self.density(),
        );
        p.histogram(
            "wisparse_queue_ms",
            "Queue wait per request (ms).",
            &self.queue_ms_hist,
        );
        p.histogram(
            "wisparse_total_ms",
            "End-to-end request latency (ms).",
            &self.total_ms_hist,
        );
        p.histogram(
            "wisparse_per_token_ms",
            "Decode-step latency per committed token (ms).",
            &self.per_token_ms_hist,
        );
        p.histogram(
            "wisparse_decode_gap_ms",
            "Wall gap between consecutive decode steps (ms).",
            &self.decode_gap_ms_hist,
        );
        for (reason, n) in &self.finished {
            p.counter(
                "wisparse_finished_total",
                "Terminal events by finish reason.",
                &[("reason", reason.as_str())],
                *n as f64,
            );
        }
        p.counter(
            "wisparse_prefill_chunks_total",
            "Prefill chunks run by the scheduler.",
            &[],
            self.prefill_chunks_total as f64,
        );
        p.counter(
            "wisparse_preemptions_total",
            "Sequences preempted for KV pool pressure.",
            &[],
            self.preemptions_total as f64,
        );
        p.counter(
            "wisparse_cancellations_total",
            "Active sequences cancelled by departed clients.",
            &[],
            self.cancellations_total as f64,
        );
        p.gauge(
            "wisparse_kv_blocks_total",
            "Paged-KV pool size in blocks.",
            &[],
            self.blocks_total as f64,
        );
        p.gauge(
            "wisparse_kv_blocks_in_use",
            "Paged-KV blocks currently referenced.",
            &[],
            self.blocks_in_use as f64,
        );
        p.counter(
            "wisparse_prefix_hit_tokens_total",
            "Prompt tokens served from the prefix cache.",
            &[],
            self.prefix_hit_tokens as f64,
        );
        p.counter(
            "wisparse_prefix_miss_tokens_total",
            "Prompt tokens missed by the prefix cache.",
            &[],
            self.prefix_miss_tokens as f64,
        );
        p.gauge(
            "wisparse_prefix_hit_rate",
            "Fraction of prompt tokens served from the prefix cache.",
            &[],
            self.prefix_hit_rate(),
        );
        p.counter(
            "wisparse_spec_rounds_total",
            "Speculative draft/verify rounds completed.",
            &[],
            self.spec_rounds_total as f64,
        );
        p.counter(
            "wisparse_spec_drafted_tokens_total",
            "Draft tokens proposed beyond each round's free token.",
            &[],
            self.spec_drafted_tokens as f64,
        );
        p.counter(
            "wisparse_spec_accepted_tokens_total",
            "Draft tokens accepted by verification.",
            &[],
            self.spec_accepted_tokens as f64,
        );
        p.gauge(
            "wisparse_spec_acceptance_rate",
            "Fraction of drafted tokens accepted.",
            &[],
            self.spec_acceptance_rate(),
        );
        p.counter(
            "wisparse_panics_caught_total",
            "Per-sequence panics converted to internal_error.",
            &[],
            self.panics_caught_total as f64,
        );
        p.counter(
            "wisparse_scheduler_restarts_total",
            "Scheduler incarnations restarted by the supervisor.",
            &[],
            self.scheduler_restarts_total as f64,
        );
        p.counter(
            "wisparse_deadline_exceeded_total",
            "Requests terminated past their deadline.",
            &[],
            self.deadline_exceeded_total as f64,
        );
        p.counter(
            "wisparse_shed_total",
            "Requests shed under overload or drain.",
            &[],
            self.shed_total as f64,
        );
        p.gauge(
            "wisparse_queue_depth",
            "Waiting (unadmitted) requests right now.",
            &[],
            self.queue_depth as f64,
        );
        p.gauge(
            "wisparse_drain_duration_ms",
            "Wall time of the last completed graceful drain.",
            &[],
            self.drain_duration_ms,
        );
        p.gauge(
            "wisparse_weight_bytes_resident",
            "Resident weight bytes of the deployed representation.",
            &[("repr", repr)],
            self.weight_bytes_resident as f64,
        );
        p.gauge(
            "wisparse_quant_compression_ratio",
            "Dense-f32 bytes over resident bytes.",
            &[],
            self.quant_compression_ratio(),
        );
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_tracks_macs() {
        let mut m = Metrics::new();
        assert_eq!(m.density(), 1.0);
        m.macs_kept = 50;
        m.macs_dense = 100;
        assert!((m.density() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_contains_fields() {
        let mut m = Metrics::new();
        m.requests_total = 3;
        m.tokens_generated = 42;
        m.queue_ms.add(1.0);
        let j = m.to_json();
        assert_eq!(j.get("requests_total").as_usize(), Some(3));
        assert_eq!(j.get("tokens_generated").as_usize(), Some(42));
        assert!(j.get("throughput_tok_s").as_f64().is_some());
        assert_eq!(j.get("blocks_total").as_usize(), Some(0));
        assert_eq!(j.get("preemptions_total").as_usize(), Some(0));
    }

    #[test]
    fn prefill_and_cancellation_gauges_serialize() {
        let mut m = Metrics::new();
        m.prefill_chunks_total = 9;
        m.cancellations_total = 2;
        for x in [1.0, 2.0, 50.0] {
            m.decode_gap_ms.add(x);
        }
        let j = m.to_json();
        assert_eq!(j.get("prefill_chunks_total").as_usize(), Some(9));
        assert_eq!(j.get("cancellations_total").as_usize(), Some(2));
        let p95 = j.get("decode_gap_ms_p95").as_f64().unwrap();
        assert!(p95 > 2.0 && p95 <= 50.0, "p95 of the window, got {p95}");
    }

    #[test]
    fn robustness_gauges_serialize() {
        let mut m = Metrics::new();
        m.panics_caught_total = 2;
        m.scheduler_restarts_total = 1;
        m.deadline_exceeded_total = 3;
        m.shed_total = 4;
        m.queue_depth = 7;
        m.drain_duration_ms = 12.5;
        let j = m.to_json();
        assert_eq!(j.get("panics_caught_total").as_usize(), Some(2));
        assert_eq!(j.get("scheduler_restarts_total").as_usize(), Some(1));
        assert_eq!(j.get("deadline_exceeded_total").as_usize(), Some(3));
        assert_eq!(j.get("shed_total").as_usize(), Some(4));
        assert_eq!(j.get("queue_depth").as_usize(), Some(7));
        assert!((j.get("drain_duration_ms").as_f64().unwrap() - 12.5).abs() < 1e-9);
    }

    #[test]
    fn spec_acceptance_rate_derivation() {
        let mut m = Metrics::new();
        assert_eq!(m.spec_acceptance_rate(), 0.0, "no rounds yet");
        m.spec_drafted_tokens = 40;
        m.spec_accepted_tokens = 30;
        assert!((m.spec_acceptance_rate() - 0.75).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get("spec_drafted_tokens").as_usize(), Some(40));
        assert!((j.get("spec_acceptance_rate").as_f64().unwrap() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn quant_gauges_derive_compression() {
        let mut m = Metrics::new();
        assert_eq!(m.quant_compression_ratio(), 1.0, "no gauges yet");
        m.weight_repr = "int8".to_string();
        m.weight_bytes_resident = 256;
        m.weight_bytes_dense = 1024;
        assert!((m.quant_compression_ratio() - 4.0).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get("weight_repr").as_str(), Some("int8"));
        assert_eq!(j.get("weight_bytes_resident").as_usize(), Some(256));
        assert!((j.get("quant_compression_ratio").as_f64().unwrap() - 4.0).abs() < 1e-12);
        let tok = j.get("decode_tok_s");
        assert!(tok.get("int8").as_f64().is_some());
        assert_eq!(tok.get("f32").as_f64(), Some(0.0));
        assert_eq!(tok.get("int4").as_f64(), Some(0.0));
    }

    #[test]
    fn prefix_hit_rate_derivation() {
        let mut m = Metrics::default();
        assert_eq!(m.prefix_hit_rate(), 0.0, "no prompts yet");
        m.prefix_hit_tokens = 75;
        m.prefix_miss_tokens = 25;
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        assert!((m.to_json().get("prefix_hit_rate").as_f64().unwrap() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn window_throughput_and_finished_serialize() {
        let mut m = Metrics::new();
        m.record_decoded(30);
        m.count_finish("length");
        m.count_finish("length");
        m.count_finish("shed");
        let j = m.to_json();
        assert!(
            j.get("throughput_window_tok_s").as_f64().unwrap() > 0.0,
            "fresh tokens show up in the window rate"
        );
        let f = j.get("finished_total");
        assert_eq!(f.get("length").as_usize(), Some(2));
        assert_eq!(f.get("shed").as_usize(), Some(1));
    }

    #[test]
    fn decode_tok_s_uses_window_not_lifetime() {
        let mut m = Metrics::new();
        m.weight_repr = "f32".to_string();
        // Lifetime counter says tokens were generated long ago; the window
        // has seen nothing. The gauge must read the window (0), not a
        // decayed lifetime average.
        m.tokens_generated = 1_000_000;
        let j = m.to_json();
        assert_eq!(j.get("decode_tok_s").get("f32").as_f64(), Some(0.0));
        m.record_decoded(60);
        let j = m.to_json();
        assert!(j.get("decode_tok_s").get("f32").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn prometheus_render_contains_families() {
        let mut m = Metrics::new();
        m.requests_total = 2;
        m.observe_queue(1.5);
        m.count_finish("length");
        let mut p = PromText::new();
        m.render_prometheus(&mut p);
        let s = p.finish();
        assert!(s.contains("# TYPE wisparse_requests_total counter"));
        assert!(s.contains("wisparse_requests_total 2"));
        assert!(s.contains("# TYPE wisparse_queue_ms histogram"));
        assert!(s.contains("wisparse_queue_ms_count 1"));
        assert!(s.contains("wisparse_queue_ms_bucket{le=\"+Inf\"} 1"));
        assert!(s.contains("wisparse_finished_total{reason=\"length\"} 1"));
        assert!(s.contains("wisparse_decode_tok_s{repr=\"f32\"}"));
    }

    #[test]
    fn build_info_in_both_views() {
        let m = Metrics::new();
        let j = m.to_json();
        let b = j.get("build_info");
        assert_eq!(b.get("version").as_str(), Some(env!("CARGO_PKG_VERSION")));
        assert!(b.get("git_sha").as_str().is_some());
        let mut p = PromText::new();
        m.render_prometheus(&mut p);
        let s = p.finish();
        assert!(s.contains("# TYPE wisparse_build_info gauge"));
        assert!(s.contains("wisparse_build_info{version=\""));
        assert!(s.contains("git_sha=\""));
        assert!(s.contains("} 1"));
    }

    #[test]
    fn slo_feed_counters_derive() {
        let mut m = Metrics::new();
        assert_eq!(m.finished_events(), 0);
        assert_eq!(m.internal_errors(), 0);
        m.count_finish("length");
        m.count_finish("internal_error");
        m.count_finish("internal_error");
        assert_eq!(m.finished_events(), 3);
        assert_eq!(m.internal_errors(), 2);
    }

    #[test]
    fn percentiles_robust_below_window_capacity() {
        // A Summary with capacity 1024 but only 3 samples must interpolate
        // over those 3 values, never uninitialized window slots.
        let mut m = Metrics::new();
        for x in [10.0, 20.0, 30.0] {
            m.per_token_ms.add(x);
        }
        let p99 = m.per_token_ms.percentile(0.99);
        assert!(
            (10.0..=30.0).contains(&p99) && p99 > 29.0,
            "p99 of 3 samples should sit just under the max, got {p99}"
        );
        assert_eq!(m.per_token_ms.percentile(0.0), 10.0);
    }
}
