//! Epoll-based single-threaded reactor front end.
//!
//! The blocking front end spends one OS thread per connection and sleep-
//! polls its accept loop; fine for a reproduction-scale router, a ceiling
//! for anything else. This reactor multiplexes every connection on one
//! thread with Linux `epoll` — raw FFI against the libc the process is
//! already linked with, mirroring the no-new-deps `signal(2)` discipline
//! of the SIGTERM drain hook — and drives each connection through an
//! explicit state machine:
//!
//!   Reading --parse--> (dispatch) --> Waiting   --resp--> write, keep-alive
//!                                 \-> Streaming --events-> write, close
//!                                 \-> immediate response (GET endpoints)
//!
//! Backpressure is explicit at both edges: per-connection write buffers
//! are bounded (a slow streaming client stops pulling tokens from its
//! channel instead of buffering without bound), and the listener is
//! disarmed while the connection table is at capacity (admission-aware
//! accept throttling — the kernel's SYN backlog absorbs the burst).
//!
//! Engine completions arrive on `mpsc` channels, which epoll cannot wait
//! on; the loop therefore polls engine-bound connections between socket
//! events, tightening its epoll timeout to ~2ms only while any exist. An
//! idle reactor parks in `epoll_wait` for 100ms at a time: idle CPU ~0.

use crate::server::faults::FaultPoint;
use crate::server::http::{
    error_status, generate_status, response_conn, route, try_parse_buffered, HttpRequest,
    READ_TIMEOUT,
};
use crate::server::request::{GenRequest, GenResponse, StreamEvent};
use crate::server::router::Router;
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::raw::c_int;
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

// --- minimal epoll/poll FFI (Linux; no external crates) ---------------------

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLRDHUP: u32 = 0x2000;
const POLLIN: i16 = 0x001;

/// `struct epoll_event`; packed on x86 ABIs (the kernel's layout), natural
/// alignment elsewhere — matching libc's definition.
#[repr(C)]
#[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

#[repr(C)]
struct PollFd {
    fd: c_int,
    events: i16,
    revents: i16,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn poll(fds: *mut PollFd, nfds: u64, timeout: c_int) -> c_int;
}

/// Park until `fd` is readable or `timeout_ms` elapses (`poll(2)`). The
/// legacy blocking front end's accept loop uses this instead of a 5ms
/// sleep-poll: a pending connection wakes it immediately, and an idle
/// listener costs a handful of wakeups per second instead of 200.
pub fn wait_readable(fd: RawFd, timeout_ms: i32) -> bool {
    let mut pfd = PollFd {
        fd,
        events: POLLIN,
        revents: 0,
    };
    unsafe { poll(&mut pfd, 1, timeout_ms) > 0 }
}

/// Thin RAII epoll instance.
struct Epoll {
    fd: c_int,
}

impl Epoll {
    fn new() -> std::io::Result<Self> {
        // EPOLL_CLOEXEC
        let fd = unsafe { epoll_create1(0o2000000) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Self { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> std::io::Result<()> {
        let mut ev = EpollEvent {
            events,
            data: token,
        };
        let p = if op == EPOLL_CTL_DEL {
            std::ptr::null_mut()
        } else {
            &mut ev as *mut EpollEvent
        };
        if unsafe { epoll_ctl(self.fd, op, fd, p) } < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    fn wait(&self, out: &mut [EpollEvent], timeout_ms: i32) -> usize {
        let n = unsafe {
            epoll_wait(
                self.fd,
                out.as_mut_ptr(),
                out.len() as c_int,
                timeout_ms,
            )
        };
        n.max(0) as usize
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

// --- reactor configuration ---------------------------------------------------

/// Reactor knobs (`wisparse serve --max-conns ...`).
#[derive(Clone, Debug)]
pub struct ReactorCfg {
    /// Connection-table capacity; the listener is disarmed at the cap.
    pub max_conns: usize,
    /// Per-connection write-buffer high-water mark: a streaming connection
    /// stops pulling token events from its channel while more than this
    /// many bytes are waiting on the socket.
    pub write_buf_cap: usize,
}

impl Default for ReactorCfg {
    fn default() -> Self {
        Self {
            max_conns: 1024,
            write_buf_cap: 256 * 1024,
        }
    }
}

/// Extra wait past a request's deadline before the reactor gives up on the
/// scheduler delivering the terminal itself (mirrors the blocking path's
/// `WAIT_GRACE`).
const WAIT_GRACE: Duration = Duration::from_secs(5);
/// Idle keep-alive connections (at least one response served) are closed
/// silently after this long; fresh connections that never complete a
/// request get a 408 after `READ_TIMEOUT` like the blocking path.
const KEEP_ALIVE_IDLE: Duration = READ_TIMEOUT;
/// Bound on buffered-but-unparsed request bytes per connection.
const MAX_CONN_BUF: usize = 2 * 1024 * 1024;

// --- per-connection state machine -------------------------------------------

enum ConnState {
    /// Accumulating request bytes.
    Reading,
    /// A non-streaming `/generate` dispatched; polling its completion.
    Waiting {
        rx: Receiver<GenResponse>,
        replica: usize,
        id: u64,
        hard: Option<Instant>,
        keep_alive: bool,
        parse_t: Instant,
        parse_ns: u64,
    },
    /// A streaming `/generate`; pulling token events into chunked writes.
    Streaming {
        rx: Receiver<StreamEvent>,
        replica: usize,
        id: u64,
        hard: Option<Instant>,
        /// Event held back by an injected `stream_stall` (chaos schedules
        /// exercising a slow consumer without blocking the reactor).
        pending: Option<StreamEvent>,
        stall_until: Option<Instant>,
    },
}

struct Conn {
    stream: TcpStream,
    state: ConnState,
    /// Unparsed request bytes.
    buf: Vec<u8>,
    /// Bounded write queue: bytes queued for the socket.
    out: Vec<u8>,
    out_pos: usize,
    /// Close once `out` is flushed (error responses, `Connection: close`).
    close_after_flush: bool,
    /// Socket reported readable and `Reading` hasn't drained it yet.
    readable: bool,
    /// Event mask currently registered with epoll.
    armed: u32,
    /// Peer hung up (EPOLLRDHUP/HUP/ERR).
    hangup: bool,
    last_activity: Instant,
    responses_served: u64,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Self {
            stream,
            state: ConnState::Reading,
            buf: Vec::new(),
            out: Vec::new(),
            out_pos: 0,
            close_after_flush: false,
            readable: true, // optimistic first read
            armed: EPOLLIN | EPOLLRDHUP,
            hangup: false,
            last_activity: Instant::now(),
            responses_served: 0,
            dead: false,
        }
    }

    fn engine_bound(&self) -> bool {
        matches!(
            self.state,
            ConnState::Waiting { .. } | ConnState::Streaming { .. }
        )
    }

    fn has_pending_out(&self) -> bool {
        self.out_pos < self.out.len()
    }

    fn push_response(&mut self, status: u16, reason: &str, content_type: &str, body: &str, keep: bool) {
        self.out
            .extend_from_slice(response_conn(status, reason, content_type, body, keep).as_bytes());
        if !keep {
            self.close_after_flush = true;
        }
        self.responses_served += 1;
        self.last_activity = Instant::now();
    }

    fn push_chunk(&mut self, data: &str) {
        self.out
            .extend_from_slice(format!("{:x}\r\n{}\r\n", data.len(), data).as_bytes());
    }

    /// Write as much of `out` as the socket accepts. Returns false when the
    /// connection died mid-write.
    fn flush(&mut self) -> bool {
        while self.out_pos < self.out.len() {
            match self.stream.write(&self.out[self.out_pos..]) {
                Ok(0) => return false,
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if self.out_pos == self.out.len() {
            self.out.clear();
            self.out_pos = 0;
        } else if self.out_pos > 64 * 1024 {
            // Reclaim the flushed prefix of a large in-flight buffer.
            self.out.drain(..self.out_pos);
            self.out_pos = 0;
        }
        true
    }

    /// Cancel the in-flight request (if any) on its replica — the client
    /// is gone, so the scheduler should free the sequence's KV blocks
    /// rather than decode for nobody.
    fn cancel_in_flight(&self, router: &Router) {
        match &self.state {
            ConnState::Waiting { replica, id, .. }
            | ConnState::Streaming { replica, id, .. } => router.cancel(*replica, *id),
            ConnState::Reading => {}
        }
    }
}

// --- the reactor itself ------------------------------------------------------

const TOKEN_LISTENER: u64 = 0;

/// Serve on `addr` with the epoll reactor until every replica behind the
/// router has shut down. Reports the bound address via `on_bound` before
/// entering the loop (bind port 0 to let the OS pick).
pub fn serve(
    router: Arc<Router>,
    addr: &str,
    cfg: ReactorCfg,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let ep = Epoll::new()?;
    ep.ctl(
        EPOLL_CTL_ADD,
        listener.as_raw_fd(),
        EPOLLIN,
        TOKEN_LISTENER,
    )?;
    let mut listener_armed = true;
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token: u64 = 1;
    let mut events = [EpollEvent { events: 0, data: 0 }; 64];

    loop {
        if router.is_shutdown() {
            break;
        }
        // Engine-bound connections wait on mpsc channels epoll can't see:
        // poll them at ~2ms. Otherwise park properly.
        let timeout = if conns.values().any(|c| c.engine_bound()) {
            2
        } else {
            100
        };
        let n = ep.wait(&mut events, timeout);
        for ev in events.iter().take(n) {
            let (token, mask) = (ev.data, ev.events);
            if token == TOKEN_LISTENER {
                accept_burst(&listener, &ep, &mut conns, &mut next_token, &cfg);
                continue;
            }
            if let Some(c) = conns.get_mut(&token) {
                if mask & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0 {
                    c.hangup = true;
                }
                if mask & EPOLLIN != 0 {
                    c.readable = true;
                }
                if mask & EPOLLOUT != 0 {
                    // Level-triggered: just try flushing on this tick.
                }
            }
        }
        tick_conns(&router, &cfg, &ep, &mut conns);
        // Rearm the listener once back under the connection cap.
        let want_armed = conns.len() < cfg.max_conns;
        if want_armed != listener_armed {
            let (op, evs) = if want_armed {
                (EPOLL_CTL_MOD, EPOLLIN)
            } else {
                (EPOLL_CTL_MOD, 0)
            };
            let _ = ep.ctl(op, listener.as_raw_fd(), evs, TOKEN_LISTENER);
            listener_armed = want_armed;
        }
    }

    // Shutdown: replicas' exit sweeps still owe terminal responses to
    // engine-bound connections. Give them (and pending writes) a bounded
    // grace to flush — a drain must deliver every response already owed,
    // not sever sockets mid-write.
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_secs(10)
        && conns
            .values()
            .any(|c| c.engine_bound() || c.has_pending_out())
    {
        let n = ep.wait(&mut events, 10);
        for ev in events.iter().take(n) {
            if let Some(c) = conns.get_mut(&ev.data) {
                if ev.events & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0 {
                    c.hangup = true;
                }
                if ev.events & EPOLLIN != 0 {
                    c.readable = true;
                }
            }
        }
        tick_conns(&router, &cfg, &ep, &mut conns);
    }
    Ok(())
}

fn accept_burst(
    listener: &TcpListener,
    ep: &Epoll,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
    cfg: &ReactorCfg,
) {
    while conns.len() < cfg.max_conns {
        match listener.accept() {
            Ok((s, _)) => {
                if s.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = s.set_nodelay(true);
                let token = *next_token;
                *next_token += 1;
                if ep
                    .ctl(EPOLL_CTL_ADD, s.as_raw_fd(), EPOLLIN | EPOLLRDHUP, token)
                    .is_ok()
                {
                    conns.insert(token, Conn::new(s));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(_) => break,
        }
    }
}

fn tick_conns(router: &Arc<Router>, cfg: &ReactorCfg, ep: &Epoll, conns: &mut HashMap<u64, Conn>) {
    let mut dead: Vec<u64> = Vec::new();
    for (tok, c) in conns.iter_mut() {
        tick_one(router, cfg, c);
        // Keep the registered mask in sync: EPOLLIN only while parsing (a
        // pipelining client must not spin the level-triggered loop while
        // its request is engine-bound), EPOLLOUT only while output is
        // pending.
        let mut want = EPOLLRDHUP;
        if matches!(c.state, ConnState::Reading) && !c.close_after_flush {
            want |= EPOLLIN;
        }
        if c.has_pending_out() {
            want |= EPOLLOUT;
        }
        if want != c.armed
            && !c.dead
            && ep
                .ctl(EPOLL_CTL_MOD, c.stream.as_raw_fd(), want, *tok)
                .is_ok()
        {
            c.armed = want;
        }
        if c.dead {
            dead.push(*tok);
        }
    }
    for tok in dead {
        if let Some(c) = conns.remove(&tok) {
            let _ = ep.ctl(EPOLL_CTL_DEL, c.stream.as_raw_fd(), 0, tok);
            // TcpStream drop closes the socket.
        }
    }
}

fn tick_one(router: &Arc<Router>, cfg: &ReactorCfg, c: &mut Conn) {
    if c.hangup {
        c.cancel_in_flight(router);
        c.dead = true;
        return;
    }
    if !c.flush() {
        c.cancel_in_flight(router);
        c.dead = true;
        return;
    }
    match &mut c.state {
        ConnState::Reading => tick_reading(router, c),
        ConnState::Waiting { .. } => tick_waiting(router, c),
        ConnState::Streaming { .. } => tick_streaming(router, cfg, c),
    }
    if !c.flush() {
        c.cancel_in_flight(router);
        c.dead = true;
        return;
    }
    if c.close_after_flush && !c.has_pending_out() && !c.engine_bound() {
        c.dead = true;
    }
}

fn tick_reading(router: &Arc<Router>, c: &mut Conn) {
    if c.readable && !c.close_after_flush {
        loop {
            let mut tmp = [0u8; 4096];
            match c.stream.read(&mut tmp) {
                Ok(0) => {
                    // EOF: a half-finished request dies silently (the
                    // client is gone); an empty connection just closes.
                    c.dead = true;
                    return;
                }
                Ok(n) => {
                    c.buf.extend_from_slice(&tmp[..n]);
                    c.last_activity = Instant::now();
                    if c.buf.len() > MAX_CONN_BUF {
                        break; // parser will reject below
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    c.readable = false;
                    break;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    c.dead = true;
                    return;
                }
            }
        }
    }
    // Parse as many pipelined requests as are buffered; stop if a dispatch
    // leaves Reading (an engine-bound request serializes the connection).
    while matches!(c.state, ConnState::Reading) && !c.close_after_flush {
        match try_parse_buffered(&c.buf) {
            None => break,
            Some(Err(e)) => {
                let (status, reason) = error_status(&e);
                c.push_response(
                    status,
                    reason,
                    "application/json",
                    &format!(r#"{{"error":"{e}"}}"#),
                    false,
                );
                break;
            }
            Some(Ok((req, consumed))) => {
                c.buf.drain(..consumed);
                dispatch(router, c, req);
            }
        }
    }
    // Timeouts: a stalled half-request gets the blocking path's 408; an
    // idle keep-alive connection closes silently.
    if matches!(c.state, ConnState::Reading) && !c.close_after_flush {
        let idle = c.last_activity.elapsed();
        if !c.buf.is_empty() || c.responses_served == 0 {
            if idle > READ_TIMEOUT {
                c.push_response(
                    408,
                    "Request Timeout",
                    "application/json",
                    r#"{"error":"read timed out"}"#,
                    false,
                );
            }
        } else if idle > KEEP_ALIVE_IDLE {
            c.dead = true;
        }
    }
}

fn dispatch(router: &Arc<Router>, c: &mut Conn, req: HttpRequest) {
    let keep = req.keep_alive;
    if req.method == "POST" && req.path == "/generate" {
        let t_parse = Instant::now();
        let parsed = Json::parse(&req.body)
            .map_err(|e| e.to_string())
            .and_then(|j| GenRequest::from_json(0, &j).map_err(|e| e.to_string()));
        let parse_ns = t_parse.elapsed().as_nanos() as u64;
        match parsed {
            Err(e) => {
                c.push_response(
                    400,
                    "Bad Request",
                    "application/json",
                    &Json::obj(vec![("error", Json::Str(e))]).to_string_compact(),
                    keep,
                );
            }
            Ok(r) if r.stream => {
                let deadline = r
                    .deadline
                    .or(router.replica(router.affinity_replica(&r.prompt)).default_deadline());
                match router.submit_stream_request(r) {
                    Err(e) => {
                        c.push_response(
                            503,
                            "Service Unavailable",
                            "application/json",
                            &Json::obj(vec![("error", Json::Str(e.to_string()))])
                                .to_string_compact(),
                            keep,
                        );
                    }
                    Ok((replica, id, rx)) => {
                        // Chunked NDJSON always closes the connection, like
                        // the blocking path.
                        c.out.extend_from_slice(
                            b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
                              Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
                        );
                        let hard = deadline.map(|d| Instant::now() + d + WAIT_GRACE);
                        c.state = ConnState::Streaming {
                            rx,
                            replica,
                            id,
                            hard,
                            pending: None,
                            stall_until: None,
                        };
                    }
                }
            }
            Ok(r) => {
                let deadline = r
                    .deadline
                    .or(router.replica(router.affinity_replica(&r.prompt)).default_deadline());
                match router.submit_request(r) {
                    Err(e) => {
                        c.push_response(
                            503,
                            "Service Unavailable",
                            "application/json",
                            &Json::obj(vec![("error", Json::Str(e.to_string()))])
                                .to_string_compact(),
                            keep,
                        );
                    }
                    Ok((replica, id, rx)) => {
                        let hard = deadline.map(|d| Instant::now() + d + WAIT_GRACE);
                        c.state = ConnState::Waiting {
                            rx,
                            replica,
                            id,
                            hard,
                            keep_alive: keep,
                            parse_t: t_parse,
                            parse_ns,
                        };
                    }
                }
            }
        }
        return;
    }
    let (status, reason, content_type, body) = route(router, &req);
    c.push_response(status, reason, content_type, &body, keep);
}

fn tick_waiting(router: &Arc<Router>, c: &mut Conn) {
    let ConnState::Waiting {
        rx,
        replica,
        id,
        hard,
        keep_alive,
        parse_t,
        parse_ns,
    } = &c.state
    else {
        return;
    };
    let (replica, id, keep) = (*replica, *id, *keep_alive);
    enum Outcome {
        Resp(GenResponse),
        Fail(String),
        Pending,
    }
    let outcome = match rx.try_recv() {
        Ok(resp) => Outcome::Resp(resp),
        Err(TryRecvError::Disconnected) => Outcome::Fail(format!("scheduler dropped request {id}")),
        Err(TryRecvError::Empty) => {
            let coord = router.replica(replica);
            if coord.scheduler_exited() {
                // The exit sweep may have delivered between the poll and
                // the flag read: drain one last time.
                match rx.try_recv() {
                    Ok(resp) => Outcome::Resp(resp),
                    Err(_) => Outcome::Fail("scheduler exited".to_string()),
                }
            } else if hard.is_some_and(|h| Instant::now() >= h) {
                router.cancel(replica, id);
                Outcome::Fail(format!("request {id} timed out waiting on the scheduler"))
            } else {
                Outcome::Pending
            }
        }
    };
    match outcome {
        Outcome::Pending => {}
        Outcome::Resp(resp) => {
            crate::obs::tracer().record_at(resp.trace_id, 0, "http_parse", *parse_t, *parse_ns, &[]);
            let (status, reason) = generate_status(&resp);
            let body = resp.to_json().to_string_pretty();
            c.state = ConnState::Reading;
            c.push_response(status, reason, "application/json", &body, keep);
        }
        Outcome::Fail(e) => {
            let body = Json::obj(vec![("error", Json::Str(e))]).to_string_compact();
            c.state = ConnState::Reading;
            c.push_response(503, "Service Unavailable", "application/json", &body, keep);
        }
    }
}

fn tick_streaming(router: &Arc<Router>, cfg: &ReactorCfg, c: &mut Conn) {
    // Backpressure: while the socket is behind, stop pulling events.
    if c.out.len() - c.out_pos > cfg.write_buf_cap {
        return;
    }
    let ConnState::Streaming {
        rx,
        replica,
        id,
        hard,
        pending,
        stall_until,
    } = &mut c.state
    else {
        return;
    };
    let (replica, id) = (*replica, *id);
    let mut lines: Vec<String> = Vec::new();
    let mut finished = false;
    let mut cancel = false;
    loop {
        if let Some(t) = *stall_until {
            if Instant::now() < t {
                break;
            }
            *stall_until = None;
        }
        let ev = match pending.take() {
            Some(ev) => ev,
            None => match rx.try_recv() {
                Ok(ev) => {
                    if router
                        .replica(replica)
                        .engine()
                        .faults
                        .should_fire(FaultPoint::StreamStall)
                    {
                        // Injected slow consumer: hold the event for 50ms
                        // without stalling the whole reactor.
                        *pending = Some(ev);
                        *stall_until = Some(Instant::now() + Duration::from_millis(50));
                        break;
                    }
                    ev
                }
                Err(TryRecvError::Disconnected) => {
                    cancel = true;
                    let done = StreamEvent::Done(GenResponse::terminal(id, "internal_error"));
                    lines.push(format!("{}\n", done.to_json().to_string_compact()));
                    finished = true;
                    break;
                }
                Err(TryRecvError::Empty) => {
                    let coord = router.replica(replica);
                    let gone = coord.scheduler_exited();
                    let expired = hard.is_some_and(|h| Instant::now() >= h);
                    if gone || expired {
                        if let Ok(ev) = rx.try_recv() {
                            // Raced the exit sweep; deliver what arrived.
                            *pending = Some(ev);
                            continue;
                        }
                        cancel = true;
                        let done =
                            StreamEvent::Done(GenResponse::terminal(id, "internal_error"));
                        lines.push(format!("{}\n", done.to_json().to_string_compact()));
                        finished = true;
                    }
                    break;
                }
            },
        };
        let done = matches!(ev, StreamEvent::Done(_));
        lines.push(format!("{}\n", ev.to_json().to_string_compact()));
        if done {
            finished = true;
            break;
        }
        if c.out.len() - c.out_pos > cfg.write_buf_cap {
            break;
        }
    }
    for line in lines {
        c.push_chunk(&line);
    }
    if cancel {
        router.cancel(replica, id);
    }
    if finished {
        c.out.extend_from_slice(b"0\r\n\r\n");
        c.state = ConnState::Reading;
        c.close_after_flush = true;
        c.responses_served += 1;
        c.last_activity = Instant::now();
    }
}
