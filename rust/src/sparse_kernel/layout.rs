//! Column-major weight layout for channel-skipping projections.

use crate::tensor::Tensor;

/// Weight matrix stored column-major: for a projection `y = x W^T` with
/// `W: [m, n]` (m outputs, n input channels), `col(c)` is the contiguous
/// m-vector of weights consuming input channel `c`. Skipping channel `c`
/// skips one contiguous read — this is what makes activation sparsity pay.
#[derive(Clone, Debug)]
pub struct ColMajorMatrix {
    /// Output dimension m.
    pub m: usize,
    /// Input dimension n (channels).
    pub n: usize,
    /// n * m values, column (input channel) major.
    pub data: Vec<f32>,
}

impl ColMajorMatrix {
    /// Convert from the row-major `[m, n]` tensor convention used by the
    /// weight files.
    pub fn from_row_major(w: &Tensor) -> Self {
        let (m, n) = w.dims2();
        let mut data = vec![0.0f32; m * n];
        for i in 0..m {
            let row = w.row(i);
            for (c, &v) in row.iter().enumerate() {
                data[c * m + i] = v;
            }
        }
        Self { m, n, data }
    }

    #[inline]
    pub fn col(&self, c: usize) -> &[f32] {
        debug_assert!(c < self.n);
        &self.data[c * self.m..(c + 1) * self.m]
    }

    /// Back to a row-major tensor (tests / reporting).
    pub fn to_row_major(&self) -> Tensor {
        let mut t = Tensor::zeros(&[self.m, self.n]);
        for c in 0..self.n {
            let col = self.col(c);
            for i in 0..self.m {
                t.data[i * self.n + c] = col[i];
            }
        }
        t
    }

    /// L2 norm of every column — `g_i` from Eq. 4, precomputed once at load.
    pub fn col_l2_norms(&self) -> Vec<f32> {
        (0..self.n)
            .map(|c| {
                self.col(c)
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum::<f64>()
                    .sqrt() as f32
            })
            .collect()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::new(8);
        let w = Tensor::randn(&[5, 9], 1.0, &mut rng);
        let cm = ColMajorMatrix::from_row_major(&w);
        assert_eq!(cm.to_row_major(), w);
    }

    #[test]
    fn col_view() {
        let w = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let cm = ColMajorMatrix::from_row_major(&w);
        assert_eq!(cm.col(0), &[1., 4.]);
        assert_eq!(cm.col(2), &[3., 6.]);
    }

    #[test]
    fn norms_match_tensor() {
        let mut rng = Pcg64::new(9);
        let w = Tensor::randn(&[6, 4], 1.0, &mut rng);
        let cm = ColMajorMatrix::from_row_major(&w);
        let a = cm.col_l2_norms();
        let b = w.col_l2_norms();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }
}
