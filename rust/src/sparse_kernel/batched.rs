//! Batched projections with *per-row* masks.
//!
//! The paper's limitation section calls out batched inference — "each
//! sequence can yield a different sparsity pattern" — as the open kernel
//! problem. Our batched kernel handles it directly: every row of the batch
//! carries its own dynamic mask (scored against the same per-layer `ga`/τ),
//! and contiguous row ranges are distributed across threads. Each worker
//! writes straight into its disjoint `ys` window and reuses one kept-index
//! scratch buffer across its rows — no per-row temporaries, no result
//! copying, no locks.

use super::gemv::{dense_gemv_simd_with, sparse_gemv_fused_with};
use super::layout::ColMajorMatrix;
use super::simd;
use crate::util::threadpool::parallel_slices_aligned;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Batched scored projection: `ys[r] = (xs[r] ⊙ m_r) W^T` with per-row
/// masks. `xs` is row-major `[rows, n]`, `ys` row-major `[rows, m]`.
/// Returns total kept channels across rows.
pub fn batched_gemm_scored(
    w: &ColMajorMatrix,
    xs: &[f32],
    rows: usize,
    ga: &[f32],
    tau: f32,
    ys: &mut [f32],
    threads: usize,
) -> usize {
    assert_eq!(xs.len(), rows * w.n);
    assert_eq!(ys.len(), rows * w.m);
    if rows == 0 {
        return 0;
    }
    let backend = simd::active();
    let n = w.n;
    let m = w.m;
    let threads = threads.max(1).min(rows);
    if threads <= 1 {
        let mut kept_idx = Vec::new();
        let mut kept = 0usize;
        for (r, y) in ys.chunks_mut(m).enumerate() {
            let x = &xs[r * n..(r + 1) * n];
            kept += sparse_gemv_fused_with(backend, w, x, Some(ga), tau, y, &mut kept_idx);
        }
        return kept;
    }
    // Rows split contiguously across threads (`align = m` keeps chunk
    // boundaries on row edges); each worker owns a disjoint window of `ys`,
    // so no synchronization is needed on the output. Kept counts reduce
    // through one atomic; each worker reuses one kept-index scratch across
    // its rows.
    let total = AtomicUsize::new(0);
    parallel_slices_aligned(ys, threads, m, |_, offset, window| {
        let base = offset / m;
        let mut kept_idx = Vec::new();
        let mut kept = 0usize;
        for (i, y) in window.chunks_mut(m).enumerate() {
            let r = base + i;
            let x = &xs[r * n..(r + 1) * n];
            kept += sparse_gemv_fused_with(backend, w, x, Some(ga), tau, y, &mut kept_idx);
        }
        total.fetch_add(kept, Ordering::Relaxed);
    });
    total.into_inner()
}

/// Batched dense projection (baseline). Both the serial and the threaded
/// path report `rows * n` kept channels (every channel of every row).
pub fn batched_gemm_dense(
    w: &ColMajorMatrix,
    xs: &[f32],
    rows: usize,
    ys: &mut [f32],
    threads: usize,
) -> usize {
    assert_eq!(xs.len(), rows * w.n);
    assert_eq!(ys.len(), rows * w.m);
    if rows == 0 {
        return 0;
    }
    let backend = simd::active();
    let n = w.n;
    let m = w.m;
    let threads = threads.max(1).min(rows);
    if threads <= 1 {
        for (r, y) in ys.chunks_mut(m).enumerate() {
            dense_gemv_simd_with(backend, w, &xs[r * n..(r + 1) * n], y);
        }
        return rows * n;
    }
    parallel_slices_aligned(ys, threads, m, |_, offset, window| {
        let base = offset / m;
        for (i, y) in window.chunks_mut(m).enumerate() {
            let r = base + i;
            dense_gemv_simd_with(backend, w, &xs[r * n..(r + 1) * n], y);
        }
    });
    rows * n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse_kernel::gemv::{dense_gemv, sparse_gemv_scored};
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    fn setup(m: usize, n: usize, rows: usize, seed: u64) -> (ColMajorMatrix, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 1.0, &mut rng));
        let xs: Vec<f32> = (0..rows * n).map(|_| rng.normal() as f32).collect();
        let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.05).collect();
        (w, xs, ga)
    }

    #[test]
    fn batched_matches_per_row_gemv() {
        let (w, xs, ga) = setup(11, 19, 5, 41);
        let mut ys_batched = vec![0.0f32; 5 * 11];
        let kept_b = batched_gemm_scored(&w, &xs, 5, &ga, 0.3, &mut ys_batched, 4);
        let mut kept_s = 0usize;
        for r in 0..5 {
            let mut y = vec![0.0f32; 11];
            kept_s += sparse_gemv_scored(&w, &xs[r * 19..(r + 1) * 19], &ga, 0.3, &mut y);
            for i in 0..11 {
                assert!((ys_batched[r * 11 + i] - y[i]).abs() < 1e-5);
            }
        }
        assert_eq!(kept_b, kept_s);
    }

    #[test]
    fn per_row_masks_differ() {
        // Construct two rows where different channels survive.
        let w = ColMajorMatrix::from_row_major(&Tensor::from_vec(
            &[1, 2],
            vec![1.0, 1.0],
        ));
        let xs = vec![10.0, 0.01, 0.01, 10.0]; // row0 keeps ch0, row1 keeps ch1
        let ga = vec![1.0, 1.0];
        let mut ys = vec![0.0f32; 2];
        let kept = batched_gemm_scored(&w, &xs, 2, &ga, 1.0, &mut ys, 1);
        assert_eq!(kept, 2);
        assert!((ys[0] - 10.0).abs() < 1e-6);
        assert!((ys[1] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn threaded_equals_single_thread() {
        let (w, xs, ga) = setup(13, 29, 16, 43);
        let mut a = vec![0.0f32; 16 * 13];
        let mut b = vec![0.0f32; 16 * 13];
        let ka = batched_gemm_scored(&w, &xs, 16, &ga, 0.25, &mut a, 1);
        let kb = batched_gemm_scored(&w, &xs, 16, &ga, 0.25, &mut b, 8);
        assert_eq!(ka, kb);
        assert_eq!(a, b);
    }

    #[test]
    fn dense_batched_matches() {
        let (w, xs, _) = setup(7, 9, 3, 47);
        let mut a = vec![0.0f32; 3 * 7];
        let mut b = vec![0.0f32; 3 * 7];
        let ka = batched_gemm_dense(&w, &xs, 3, &mut a, 1);
        let kb = batched_gemm_dense(&w, &xs, 3, &mut b, 4);
        assert_eq!(ka, 3 * 9);
        assert_eq!(ka, kb, "kept counts must agree across thread counts");
        assert_eq!(a, b);
        // And against the reference row-by-row kernel.
        let mut reference = vec![0.0f32; 7];
        for r in 0..3 {
            dense_gemv(&w, &xs[r * 9..(r + 1) * 9], &mut reference);
            for i in 0..7 {
                assert!((a[r * 7 + i] - reference[i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn empty_batch() {
        let (w, _, ga) = setup(4, 6, 1, 53);
        let mut ys = vec![];
        assert_eq!(batched_gemm_scored(&w, &[], 0, &ga, 0.1, &mut ys, 4), 0);
        assert_eq!(batched_gemm_dense(&w, &[], 0, &mut ys, 4), 0);
    }

    #[test]
    fn uneven_row_split() {
        // rows not divisible by threads: last window is short.
        let (w, xs, ga) = setup(9, 17, 7, 59);
        let mut a = vec![0.0f32; 7 * 9];
        let mut b = vec![0.0f32; 7 * 9];
        let ka = batched_gemm_scored(&w, &xs, 7, &ga, 0.2, &mut a, 1);
        let kb = batched_gemm_scored(&w, &xs, 7, &ga, 0.2, &mut b, 3);
        assert_eq!(ka, kb);
        assert_eq!(a, b);
    }
}
