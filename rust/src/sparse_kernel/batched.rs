//! Batched projections with *per-row* masks.
//!
//! The paper's limitation section calls out batched inference — "each
//! sequence can yield a different sparsity pattern" — as the open kernel
//! problem. Our batched kernel handles it directly: every row of the batch
//! carries its own dynamic mask (scored against the same per-layer `ga`/τ),
//! and rows are distributed across threads. This is the "improved sparse
//! kernels" piece of the reproduction.

use super::gemv::{dense_gemv, sparse_gemv_scored};
use super::layout::ColMajorMatrix;
use crate::util::threadpool::parallel_map;

/// Batched scored projection: `ys[r] = (xs[r] ⊙ m_r) W^T` with per-row
/// masks. `xs` is row-major `[rows, n]`, `ys` row-major `[rows, m]`.
/// Returns total kept channels across rows.
pub fn batched_gemm_scored(
    w: &ColMajorMatrix,
    xs: &[f32],
    rows: usize,
    ga: &[f32],
    tau: f32,
    ys: &mut [f32],
    threads: usize,
) -> usize {
    assert_eq!(xs.len(), rows * w.n);
    assert_eq!(ys.len(), rows * w.m);
    if rows == 0 {
        return 0;
    }
    if threads <= 1 || rows == 1 {
        let mut kept = 0;
        for r in 0..rows {
            let x = &xs[r * w.n..(r + 1) * w.n];
            let y = &mut ys[r * w.m..(r + 1) * w.m];
            kept += sparse_gemv_scored(w, x, ga, tau, y);
        }
        return kept;
    }
    // Work-stealing over rows; each row writes a disjoint output slice, so
    // we hand out raw row buffers via index math inside parallel_map.
    let m = w.m;
    let n = w.n;
    let results = parallel_map(rows, threads, |r| {
        let x = &xs[r * n..(r + 1) * n];
        let mut y = vec![0.0f32; m];
        let kept = sparse_gemv_scored(w, x, ga, tau, &mut y);
        (r, y, kept)
    });
    let mut total = 0usize;
    for (r, y, kept) in results {
        ys[r * m..(r + 1) * m].copy_from_slice(&y);
        total += kept;
    }
    total
}

/// Batched dense projection (baseline).
pub fn batched_gemm_dense(
    w: &ColMajorMatrix,
    xs: &[f32],
    rows: usize,
    ys: &mut [f32],
    threads: usize,
) -> usize {
    assert_eq!(xs.len(), rows * w.n);
    assert_eq!(ys.len(), rows * w.m);
    if threads <= 1 || rows <= 1 {
        for r in 0..rows {
            let x = &xs[r * w.n..(r + 1) * w.n];
            let y = &mut ys[r * w.m..(r + 1) * w.m];
            dense_gemv(w, x, y);
        }
        return rows * w.n;
    }
    let m = w.m;
    let n = w.n;
    let results = parallel_map(rows, threads, |r| {
        let x = &xs[r * n..(r + 1) * n];
        let mut y = vec![0.0f32; m];
        dense_gemv(w, x, &mut y);
        (r, y)
    });
    for (r, y) in results {
        ys[r * m..(r + 1) * m].copy_from_slice(&y);
    }
    rows * w.n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    fn setup(m: usize, n: usize, rows: usize, seed: u64) -> (ColMajorMatrix, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 1.0, &mut rng));
        let xs: Vec<f32> = (0..rows * n).map(|_| rng.normal() as f32).collect();
        let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.05).collect();
        (w, xs, ga)
    }

    #[test]
    fn batched_matches_per_row_gemv() {
        let (w, xs, ga) = setup(11, 19, 5, 41);
        let mut ys_batched = vec![0.0f32; 5 * 11];
        let kept_b = batched_gemm_scored(&w, &xs, 5, &ga, 0.3, &mut ys_batched, 4);
        let mut kept_s = 0usize;
        for r in 0..5 {
            let mut y = vec![0.0f32; 11];
            kept_s += sparse_gemv_scored(&w, &xs[r * 19..(r + 1) * 19], &ga, 0.3, &mut y);
            for i in 0..11 {
                assert!((ys_batched[r * 11 + i] - y[i]).abs() < 1e-5);
            }
        }
        assert_eq!(kept_b, kept_s);
    }

    #[test]
    fn per_row_masks_differ() {
        // Construct two rows where different channels survive.
        let w = ColMajorMatrix::from_row_major(&Tensor::from_vec(
            &[1, 2],
            vec![1.0, 1.0],
        ));
        let xs = vec![10.0, 0.01, 0.01, 10.0]; // row0 keeps ch0, row1 keeps ch1
        let ga = vec![1.0, 1.0];
        let mut ys = vec![0.0f32; 2];
        let kept = batched_gemm_scored(&w, &xs, 2, &ga, 1.0, &mut ys, 1);
        assert_eq!(kept, 2);
        assert!((ys[0] - 10.0).abs() < 1e-6);
        assert!((ys[1] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn threaded_equals_single_thread() {
        let (w, xs, ga) = setup(13, 29, 16, 43);
        let mut a = vec![0.0f32; 16 * 13];
        let mut b = vec![0.0f32; 16 * 13];
        let ka = batched_gemm_scored(&w, &xs, 16, &ga, 0.25, &mut a, 1);
        let kb = batched_gemm_scored(&w, &xs, 16, &ga, 0.25, &mut b, 8);
        assert_eq!(ka, kb);
        assert_eq!(a, b);
    }

    #[test]
    fn dense_batched_matches() {
        let (w, xs, _) = setup(7, 9, 3, 47);
        let mut a = vec![0.0f32; 3 * 7];
        let mut b = vec![0.0f32; 3 * 7];
        batched_gemm_dense(&w, &xs, 3, &mut a, 1);
        batched_gemm_dense(&w, &xs, 3, &mut b, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_batch() {
        let (w, _, ga) = setup(4, 6, 1, 53);
        let mut ys = vec![];
        assert_eq!(batched_gemm_scored(&w, &[], 0, &ga, 0.1, &mut ys, 4), 0);
    }
}
