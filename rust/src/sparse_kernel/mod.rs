//! The performance-critical sparse linear-projection kernels (L3 hot path).
//!
//! The paper's efficiency claim rests on converting *channel* sparsity into
//! skipped memory traffic and FLOPs inside `y = (x ⊙ m) W^T`. We store every
//! weight matrix column-major (one contiguous slice per *input channel*), so
//! skipping a pruned channel skips exactly its column read and its
//! multiply-accumulate — the same mechanism as TEAL's gather kernels, mapped
//! to CPU SIMD instead of CUDA threadblocks (see DESIGN.md §2, §6).

pub mod layout;
pub mod simd;
pub mod gemv;
pub mod batched;

pub use gemv::{
    dense_gemv, dense_gemv_parallel, sparse_gemv_fused, sparse_gemv_fused_parallel,
    sparse_gemv_indices, sparse_gemv_scored, sparse_gemv_threshold,
};
pub use layout::ColMajorMatrix;
pub use simd::Backend;
