//! Channel-skipping GEMV kernels.
//!
//! All kernels compute `y = x_S (W[:,S])^T` (Eq. 3) for different ways of
//! choosing `S`, and return `|S|` so the engine can account actual FLOPs.
//! They accumulate with a single pass over kept channels; each kept channel
//! contributes one contiguous AXPY over the output vector, which the
//! compiler auto-vectorizes.

use super::layout::ColMajorMatrix;

/// Dense projection (S = all channels). Baseline for the speedup plots.
pub fn dense_gemv(w: &ColMajorMatrix, x: &[f32], out: &mut [f32]) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    for (c, &xv) in x.iter().enumerate() {
        axpy(xv, w.col(c), out);
    }
    w.n
}

/// WiSparse / WINA scored projection: keep channel c iff
/// `|x_c| * ga_c >= tau`, where `ga_c = g_c^alpha` is precomputed (Eq. 4-5).
/// Scoring is fused into the accumulation pass — the per-channel overhead is
/// one abs, one multiply and one compare, matching the paper's "negligible
/// overhead" claim.
pub fn sparse_gemv_scored(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: &[f32],
    tau: f32,
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(ga.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    let mut kept = 0usize;
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() * ga[c] >= tau {
            axpy(xv, w.col(c), out);
            kept += 1;
        }
    }
    kept
}

/// TEAL-style magnitude thresholding: keep iff `|x_c| >= tau`.
pub fn sparse_gemv_threshold(
    w: &ColMajorMatrix,
    x: &[f32],
    tau: f32,
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    let mut kept = 0usize;
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() >= tau {
            axpy(xv, w.col(c), out);
            kept += 1;
        }
    }
    kept
}

/// Projection over an explicit channel index set (R-Sparse's top-k path,
/// and the generic fallback).
pub fn sparse_gemv_indices(
    w: &ColMajorMatrix,
    x: &[f32],
    channels: &[usize],
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    for &c in channels {
        axpy(x[c], w.col(c), out);
    }
    channels.len()
}

/// Scored projection that additionally writes the kept-channel indices into
/// `kept_buf` (used by R-Sparse to route the complement through the low-rank
/// path, and by diagnostics).
pub fn sparse_gemv_scored_collect(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: &[f32],
    tau: f32,
    out: &mut [f32],
    kept_buf: &mut Vec<usize>,
) -> usize {
    out.fill(0.0);
    kept_buf.clear();
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() * ga[c] >= tau {
            axpy(xv, w.col(c), out);
            kept_buf.push(c);
        }
    }
    kept_buf.len()
}

/// out += a * col. The single hot loop of the engine; kept free of bounds
/// checks via exact-length slices so LLVM vectorizes it.
#[inline]
pub fn axpy(a: f32, col: &[f32], out: &mut [f32]) {
    if a == 0.0 {
        return;
    }
    let n = out.len();
    debug_assert_eq!(col.len(), n);
    let (col, out) = (&col[..n], &mut out[..n]);
    for i in 0..n {
        out[i] += a * col[i];
    }
}

/// Scored projection with 4-column fused accumulation (§Perf optimization):
/// kept channels are batched in groups of four so the output vector is
/// loaded/stored once per four AXPYs instead of once per AXPY, quartering
/// the dominant store traffic of the skinny-GEMV regime.
pub fn sparse_gemv_scored_x4(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: &[f32],
    tau: f32,
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(ga.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    let m = w.m;
    let mut kept = 0usize;
    // Pending (coefficient, column offset) pairs awaiting a fused flush.
    let mut coeffs = [0.0f32; 4];
    let mut offs = [0usize; 4];
    let mut pending = 0usize;
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() * ga[c] >= tau {
            coeffs[pending] = xv;
            offs[pending] = c * m;
            pending += 1;
            kept += 1;
            if pending == 4 {
                axpy4(&coeffs, &offs, &w.data, out);
                pending = 0;
            }
        }
    }
    for p in 0..pending {
        axpy(coeffs[p], &w.data[offs[p]..offs[p] + m], out);
    }
    kept
}

/// out += sum_j coeffs[j] * data[offs[j]..offs[j]+m]. All four columns are
/// walked in lockstep; LLVM vectorizes the inner loop into FMA chains.
#[inline]
fn axpy4(coeffs: &[f32; 4], offs: &[usize; 4], data: &[f32], out: &mut [f32]) {
    let m = out.len();
    let (a0, a1, a2, a3) = (coeffs[0], coeffs[1], coeffs[2], coeffs[3]);
    let c0 = &data[offs[0]..offs[0] + m];
    let c1 = &data[offs[1]..offs[1] + m];
    let c2 = &data[offs[2]..offs[2] + m];
    let c3 = &data[offs[3]..offs[3] + m];
    for i in 0..m {
        out[i] += a0 * c0[i] + a1 * c1[i] + a2 * c2[i] + a3 * c3[i];
    }
}

/// Count of channels a scored mask keeps (no compute) — used by FLOP
/// accounting dry-runs and tests.
pub fn count_kept_scored(x: &[f32], ga: &[f32], tau: f32) -> usize {
    x.iter()
        .zip(ga)
        .filter(|(&xv, &g)| xv.abs() * g >= tau)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_xwt;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    fn setup(m: usize, n: usize, seed: u64) -> (Tensor, ColMajorMatrix, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let w = Tensor::randn(&[m, n], 1.0, &mut rng);
        let cm = ColMajorMatrix::from_row_major(&w);
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        (w, cm, x)
    }

    #[test]
    fn dense_matches_reference_matmul() {
        let (w, cm, x) = setup(17, 23, 31);
        let mut out = vec![0.0f32; 17];
        let kept = dense_gemv(&cm, &x, &mut out);
        assert_eq!(kept, 23);
        let xr = Tensor::from_vec(&[1, 23], x.clone());
        let expect = matmul_xwt(&xr, &w);
        for i in 0..17 {
            assert!((out[i] - expect.data[i]).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn scored_with_zero_tau_keeps_all() {
        let (_, cm, x) = setup(8, 12, 5);
        let ga = vec![1.0f32; 12];
        let mut dense = vec![0.0f32; 8];
        let mut scored = vec![0.0f32; 8];
        dense_gemv(&cm, &x, &mut dense);
        let kept = sparse_gemv_scored(&cm, &x, &ga, 0.0, &mut scored);
        assert_eq!(kept, 12);
        assert_eq!(dense, scored);
    }

    #[test]
    fn scored_equals_masked_reference() {
        let (w, cm, x) = setup(10, 20, 7);
        let mut rng = Pcg64::new(99);
        let ga: Vec<f32> = (0..20).map(|_| rng.next_f32() + 0.1).collect();
        let tau = 0.5f32;
        // Reference: zero masked channels, dense matmul.
        let masked: Vec<f32> = x
            .iter()
            .zip(&ga)
            .map(|(&xv, &g)| if xv.abs() * g >= tau { xv } else { 0.0 })
            .collect();
        let expect = matmul_xwt(&Tensor::from_vec(&[1, 20], masked.clone()), &w);
        let mut out = vec![0.0f32; 10];
        let kept = sparse_gemv_scored(&cm, &x, &ga, tau, &mut out);
        assert_eq!(kept, masked.iter().filter(|&&v| v != 0.0).count());
        for i in 0..10 {
            assert!((out[i] - expect.data[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn threshold_is_scored_with_unit_ga() {
        let (_, cm, x) = setup(6, 15, 13);
        let ga = vec![1.0f32; 15];
        let mut a = vec![0.0f32; 6];
        let mut b = vec![0.0f32; 6];
        let ka = sparse_gemv_threshold(&cm, &x, 0.7, &mut a);
        let kb = sparse_gemv_scored(&cm, &x, &ga, 0.7, &mut b);
        assert_eq!(ka, kb);
        assert_eq!(a, b);
    }

    #[test]
    fn indices_variant_matches() {
        let (_, cm, x) = setup(9, 14, 17);
        let channels: Vec<usize> = vec![0, 3, 7, 13];
        let mut by_idx = vec![0.0f32; 9];
        sparse_gemv_indices(&cm, &x, &channels, &mut by_idx);
        // Equivalent dense with zeroed complement.
        let mut xz = vec![0.0f32; 14];
        for &c in &channels {
            xz[c] = x[c];
        }
        let mut by_dense = vec![0.0f32; 9];
        dense_gemv(&cm, &xz, &mut by_dense);
        for i in 0..9 {
            assert!((by_idx[i] - by_dense[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn collect_reports_kept_channels() {
        let (_, cm, x) = setup(4, 10, 19);
        let ga = vec![1.0f32; 10];
        let mut out = vec![0.0f32; 4];
        let mut kept = Vec::new();
        sparse_gemv_scored_collect(&cm, &x, &ga, 0.4, &mut out, &mut kept);
        for &c in &kept {
            assert!(x[c].abs() >= 0.4);
        }
        for c in 0..10 {
            if !kept.contains(&c) {
                assert!(x[c].abs() < 0.4);
            }
        }
        assert_eq!(kept.len(), count_kept_scored(&x, &ga, 0.4));
    }

    #[test]
    fn x4_variant_matches_scalar() {
        for seed in [3u64, 7, 11, 13] {
            let (_, cm, x) = setup(23, 37, seed);
            let mut rng = Pcg64::new(seed ^ 0xF0);
            let ga: Vec<f32> = (0..37).map(|_| rng.next_f32() + 0.05).collect();
            for tau in [0.0f32, 0.2, 0.6, 1.4, f32::INFINITY] {
                let mut a = vec![0.0f32; 23];
                let mut b = vec![0.0f32; 23];
                let ka = sparse_gemv_scored(&cm, &x, &ga, tau, &mut a);
                let kb = sparse_gemv_scored_x4(&cm, &x, &ga, tau, &mut b);
                assert_eq!(ka, kb, "tau {tau}");
                for i in 0..23 {
                    assert!((a[i] - b[i]).abs() < 1e-4, "tau {tau} row {i}");
                }
            }
        }
    }

    #[test]
    fn infinite_tau_keeps_nothing() {
        let (_, cm, x) = setup(5, 8, 23);
        let ga = vec![1.0f32; 8];
        let mut out = vec![1.0f32; 5];
        let kept = sparse_gemv_scored(&cm, &x, &ga, f32::INFINITY, &mut out);
        assert_eq!(kept, 0);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
