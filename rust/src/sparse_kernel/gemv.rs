//! Channel-skipping GEMV kernels.
//!
//! All kernels compute `y = x_S (W[:,S])^T` (Eq. 3) for different ways of
//! choosing `S`, and return `|S|` so the engine can account actual FLOPs.
//! They accumulate with a single pass over kept channels; each kept channel
//! contributes one contiguous AXPY over the output vector, which the
//! compiler auto-vectorizes.

use super::layout::ColMajorMatrix;
use super::simd::{self, Backend};
use crate::util::threadpool::{parallel_row_windows, parallel_slices_aligned, SendPtr};
use std::cell::RefCell;

/// Minimum multiply-accumulates before intra-GEMV row parallelism pays for
/// its thread fork-join. Below this the fused kernels run on the calling
/// thread (micro/nano model shapes never split; `lm_head`-sized projections
/// on real vocabularies do).
pub const PAR_MIN_MACS: usize = 1 << 21;

/// Dense projection (S = all channels). Baseline for the speedup plots.
pub fn dense_gemv(w: &ColMajorMatrix, x: &[f32], out: &mut [f32]) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    for (c, &xv) in x.iter().enumerate() {
        axpy(xv, w.col(c), out);
    }
    w.n
}

/// WiSparse / WINA scored projection: keep channel c iff
/// `|x_c| * ga_c >= tau`, where `ga_c = g_c^alpha` is precomputed (Eq. 4-5).
/// Scoring is fused into the accumulation pass — the per-channel overhead is
/// one abs, one multiply and one compare, matching the paper's "negligible
/// overhead" claim.
pub fn sparse_gemv_scored(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: &[f32],
    tau: f32,
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(ga.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    let mut kept = 0usize;
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() * ga[c] >= tau {
            axpy(xv, w.col(c), out);
            kept += 1;
        }
    }
    kept
}

/// TEAL-style magnitude thresholding: keep iff `|x_c| >= tau`.
pub fn sparse_gemv_threshold(
    w: &ColMajorMatrix,
    x: &[f32],
    tau: f32,
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    let mut kept = 0usize;
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() >= tau {
            axpy(xv, w.col(c), out);
            kept += 1;
        }
    }
    kept
}

/// Projection over an explicit channel index set (R-Sparse's top-k path,
/// and the generic fallback).
pub fn sparse_gemv_indices(
    w: &ColMajorMatrix,
    x: &[f32],
    channels: &[usize],
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    for &c in channels {
        axpy(x[c], w.col(c), out);
    }
    channels.len()
}

/// Scored projection that additionally writes the kept-channel indices into
/// `kept_buf` (used by R-Sparse to route the complement through the low-rank
/// path, and by diagnostics).
pub fn sparse_gemv_scored_collect(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: &[f32],
    tau: f32,
    out: &mut [f32],
    kept_buf: &mut Vec<usize>,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(ga.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    kept_buf.clear();
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() * ga[c] >= tau {
            axpy(xv, w.col(c), out);
            kept_buf.push(c);
        }
    }
    kept_buf.len()
}

/// out += a * col. The single hot loop of the engine; kept free of bounds
/// checks via exact-length slices so LLVM vectorizes it.
#[inline]
pub fn axpy(a: f32, col: &[f32], out: &mut [f32]) {
    if a == 0.0 {
        return;
    }
    let n = out.len();
    debug_assert_eq!(col.len(), n);
    let (col, out) = (&col[..n], &mut out[..n]);
    for i in 0..n {
        out[i] += a * col[i];
    }
}

/// Scored projection with 4-column fused accumulation (§Perf optimization):
/// kept channels are batched in groups of four so the output vector is
/// loaded/stored once per four AXPYs instead of once per AXPY, quartering
/// the dominant store traffic of the skinny-GEMV regime.
pub fn sparse_gemv_scored_x4(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: &[f32],
    tau: f32,
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(ga.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    let m = w.m;
    let mut kept = 0usize;
    // Pending (coefficient, column offset) pairs awaiting a fused flush.
    let mut coeffs = [0.0f32; 4];
    let mut offs = [0usize; 4];
    let mut pending = 0usize;
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() * ga[c] >= tau {
            coeffs[pending] = xv;
            offs[pending] = c * m;
            pending += 1;
            kept += 1;
            if pending == 4 {
                axpy4(&coeffs, &offs, &w.data, out);
                pending = 0;
            }
        }
    }
    for p in 0..pending {
        axpy(coeffs[p], &w.data[offs[p]..offs[p] + m], out);
    }
    kept
}

/// out += sum_j coeffs[j] * data[offs[j]..offs[j]+m]. All four columns are
/// walked in lockstep; LLVM vectorizes the inner loop into FMA chains.
#[inline]
fn axpy4(coeffs: &[f32; 4], offs: &[usize; 4], data: &[f32], out: &mut [f32]) {
    let m = out.len();
    let (a0, a1, a2, a3) = (coeffs[0], coeffs[1], coeffs[2], coeffs[3]);
    let c0 = &data[offs[0]..offs[0] + m];
    let c1 = &data[offs[1]..offs[1] + m];
    let c2 = &data[offs[2]..offs[2] + m];
    let c3 = &data[offs[3]..offs[3] + m];
    for i in 0..m {
        out[i] += a0 * c0[i] + a1 * c1[i] + a2 * c2[i] + a3 * c3[i];
    }
}

// ---------------------------------------------------------------------------
// Two-pass fused kernels (SIMD backend, §Tentpole): pass 1 scans the mask
// predicate into a reusable index buffer, pass 2 accumulates kept columns in
// fused groups of eight so the output vector is loaded/stored once per eight
// AXPYs. `ga = None` is the TEAL/magnitude path — it gets the same fused
// treatment, which the single-pass kernels above never gave it.
// ---------------------------------------------------------------------------

/// Fused scored/threshold projection on the process-wide SIMD backend.
/// `kept_idx` is caller-owned scratch (no allocation once warm).
pub fn sparse_gemv_fused(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
) -> usize {
    sparse_gemv_fused_with(simd::active(), w, x, ga, tau, out, kept_idx)
}

/// Fused projection on an explicit backend (tests / bench sweeps).
pub fn sparse_gemv_fused_with(
    backend: Backend,
    w: &ColMajorMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    match ga {
        Some(ga) => {
            debug_assert_eq!(ga.len(), w.n);
            simd::scan_scored_with(backend, x, ga, tau, kept_idx);
        }
        None => simd::scan_threshold_with(backend, x, tau, kept_idx),
    }
    out.fill(0.0);
    accum_rows(backend, w, x, kept_idx, 0, out);
    kept_idx.len()
}

/// Fused projection with intra-GEMV row parallelism: when the kept work is
/// large enough (`PAR_MIN_MACS`), the output range is split into contiguous
/// row windows across `threads`, each walking the same kept-index list over
/// its own column sub-slices. Window boundaries are aligned to the SIMD
/// group width, so every element lands in the same vector-body/scalar-tail
/// position as in the serial kernel and the result is bit-identical at any
/// thread count.
pub fn sparse_gemv_fused_parallel(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
    threads: usize,
) -> usize {
    sparse_gemv_fused_parallel_with(
        simd::active(),
        w,
        x,
        ga,
        tau,
        out,
        kept_idx,
        threads,
        PAR_MIN_MACS,
    )
}

/// As [`sparse_gemv_fused_parallel`] with explicit backend and split
/// threshold (tests force `min_macs = 0` to exercise the split path on
/// small shapes).
#[allow(clippy::too_many_arguments)]
pub fn sparse_gemv_fused_parallel_with(
    backend: Backend,
    w: &ColMajorMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
    threads: usize,
    min_macs: usize,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    match ga {
        Some(ga) => {
            debug_assert_eq!(ga.len(), w.n);
            simd::scan_scored_with(backend, x, ga, tau, kept_idx);
        }
        None => simd::scan_threshold_with(backend, x, tau, kept_idx),
    }
    let kept = kept_idx.len();
    if threads <= 1 || w.m.saturating_mul(kept) < min_macs.max(1) {
        out.fill(0.0);
        accum_rows(backend, w, x, kept_idx, 0, out);
        return kept;
    }
    let idx: &[u32] = kept_idx.as_slice();
    parallel_slices_aligned(out, threads, 8, |_, row0, rows| {
        rows.fill(0.0);
        accum_rows(backend, w, x, idx, row0, rows);
    });
    kept
}

/// Dense projection on an explicit SIMD backend (all channels kept; no scan
/// or index buffer needed).
pub fn dense_gemv_simd_with(
    backend: Backend,
    w: &ColMajorMatrix,
    x: &[f32],
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    dense_rows(backend, w, x, 0, out);
    w.n
}

/// Dense projection with intra-GEMV row parallelism — the `lm_head` path of
/// single-sequence decode, where the output dim (vocab) dwarfs every other
/// projection.
pub fn dense_gemv_parallel(
    w: &ColMajorMatrix,
    x: &[f32],
    out: &mut [f32],
    threads: usize,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    let backend = simd::active();
    if threads <= 1 || w.m.saturating_mul(w.n) < PAR_MIN_MACS {
        out.fill(0.0);
        dense_rows(backend, w, x, 0, out);
        return w.n;
    }
    parallel_slices_aligned(out, threads, 8, |_, row0, rows| {
        rows.fill(0.0);
        dense_rows(backend, w, x, row0, rows);
    });
    w.n
}

/// rows += sum over kept channels of `x[c] * W[row0..row0+rows.len(), c]`,
/// fused eight columns at a time.
fn accum_rows(
    backend: Backend,
    w: &ColMajorMatrix,
    x: &[f32],
    idx: &[u32],
    row0: usize,
    rows: &mut [f32],
) {
    let m = w.m;
    debug_assert!(row0 + rows.len() <= m);
    let mut coeffs = [0.0f32; 8];
    let mut offs = [0usize; 8];
    let groups = idx.chunks_exact(8);
    let rem = groups.remainder();
    for group in groups {
        for (j, &c) in group.iter().enumerate() {
            let c = c as usize;
            coeffs[j] = x[c];
            offs[j] = c * m + row0;
        }
        simd::axpy8_with(backend, &coeffs, &offs, &w.data, rows);
    }
    for &c in rem {
        let c = c as usize;
        let lo = c * m + row0;
        simd::axpy_with(backend, x[c], &w.data[lo..lo + rows.len()], rows);
    }
}

/// rows += `x W[row0..row0+rows.len(), :]^T` over every channel, fused eight
/// columns at a time (dense counterpart of [`accum_rows`]).
fn dense_rows(backend: Backend, w: &ColMajorMatrix, x: &[f32], row0: usize, rows: &mut [f32]) {
    let m = w.m;
    let n = w.n;
    debug_assert!(row0 + rows.len() <= m);
    let mut coeffs = [0.0f32; 8];
    let mut offs = [0usize; 8];
    let mut c = 0usize;
    while c + 8 <= n {
        for j in 0..8 {
            coeffs[j] = x[c + j];
            offs[j] = (c + j) * m + row0;
        }
        simd::axpy8_with(backend, &coeffs, &offs, &w.data, rows);
        c += 8;
    }
    while c < n {
        let lo = c * m + row0;
        simd::axpy_with(backend, x[c], &w.data[lo..lo + rows.len()], rows);
        c += 1;
    }
}

// ---------------------------------------------------------------------------
// Batch-fused kernels (§Tentpole, PR 8): one weight walk shared by every
// position of a decode batch. Pass 1 scans each position's mask exactly as
// the per-sequence kernels do (identical kept sets, per-position tau/ga
// preserved); pass 2 merge-walks the *union* of the kept lists in ascending
// column order, so each kept weight column is streamed from memory once no
// matter how many positions keep it. Every position accumulates through its
// own pending group of eight, reproducing `accum_rows`' exact flush grouping
// — the output is bit-identical to running the per-sequence kernel per
// position.
//
// Inputs and outputs are strided row-major stacks: position `p` reads
// `xs[p*in_stride..][..n]` and writes `outs[p*out_stride..][..m]`.
// ---------------------------------------------------------------------------

thread_local! {
    /// Per-position kept-index lists for the batch scan (reused across calls
    /// so the steady-state fused decode step never allocates).
    static BATCH_IDX: RefCell<Vec<Vec<u32>>> = const { RefCell::new(Vec::new()) };
    /// Merge cursors + pending flush groups for the union walk.
    static WALK_SCRATCH: RefCell<WalkScratch> = RefCell::new(WalkScratch::default());
}

#[derive(Default)]
struct WalkScratch {
    cur: Vec<usize>,
    pend: Vec<[u32; 8]>,
    pn: Vec<u8>,
}

/// Scan each position's mask into the reusable per-thread kept-index lists,
/// then hand the populated lists to `body` (shared by the f32 and quant
/// batch kernels). `cap` is the worst-case kept count (the channel dim):
/// each list is grown to it *before* the scan, so a later step that keeps
/// more channels than any earlier one never reallocates mid-steady-state.
pub(crate) fn with_scanned_batch<R>(
    n_pos: usize,
    cap: usize,
    mut scan: impl FnMut(usize, &mut Vec<u32>),
    body: impl FnOnce(&[Vec<u32>]) -> R,
) -> R {
    BATCH_IDX.with(|cell| {
        let all = &mut *cell.borrow_mut();
        if all.len() < n_pos {
            all.resize_with(n_pos, Vec::new);
        }
        for (p, l) in all.iter_mut().enumerate().take(n_pos) {
            if l.capacity() < cap {
                l.reserve(cap.saturating_sub(l.len()));
            }
            scan(p, l);
        }
        body(&all[..n_pos])
    })
}

impl WalkScratch {
    fn ensure(&mut self, n_pos: usize) {
        if self.cur.len() < n_pos {
            self.cur.resize(n_pos, 0);
            self.pend.resize(n_pos, [0u32; 8]);
            self.pn.resize(n_pos, 0);
        }
    }
}

/// Distinct columns across the per-position kept lists (each sorted
/// ascending) — the number of weight columns the fused walk streams.
pub(crate) fn union_count(idx: &[Vec<u32>]) -> usize {
    if idx.len() == 1 {
        return idx[0].len();
    }
    WALK_SCRATCH.with(|cell| {
        let ws = &mut *cell.borrow_mut();
        ws.ensure(idx.len());
        let cur = &mut ws.cur[..idx.len()];
        cur.fill(0);
        let mut union = 0usize;
        loop {
            let mut c_min = u32::MAX;
            for (p, l) in idx.iter().enumerate() {
                if cur[p] < l.len() && l[cur[p]] < c_min {
                    c_min = l[cur[p]];
                }
            }
            if c_min == u32::MAX {
                break;
            }
            union += 1;
            for (p, l) in idx.iter().enumerate() {
                if cur[p] < l.len() && l[cur[p]] == c_min {
                    cur[p] += 1;
                }
            }
        }
        union
    })
}

/// Drives the union merge-walk shared by the f32 and quant batch kernels:
/// visits each distinct kept column once in ascending order, staging it into
/// the pending group of every position that keeps it. `flush8(p, cols)`
/// fires when position `p`'s group fills; `flush1(p, c)` drains each
/// position's `< 8` tail ascending afterwards — byte-for-byte the grouping
/// `accum_rows` gives each position on its own.
pub(crate) fn merge_walk_groups(
    idx: &[Vec<u32>],
    mut flush8: impl FnMut(usize, &[u32; 8]),
    mut flush1: impl FnMut(usize, u32),
) {
    let n_pos = idx.len();
    WALK_SCRATCH.with(|cell| {
        let ws = &mut *cell.borrow_mut();
        ws.ensure(n_pos);
        let cur = &mut ws.cur[..n_pos];
        let pend = &mut ws.pend[..n_pos];
        let pn = &mut ws.pn[..n_pos];
        cur.fill(0);
        pn.fill(0);
        loop {
            let mut c_min = u32::MAX;
            for p in 0..n_pos {
                if cur[p] < idx[p].len() && idx[p][cur[p]] < c_min {
                    c_min = idx[p][cur[p]];
                }
            }
            if c_min == u32::MAX {
                break;
            }
            for p in 0..n_pos {
                if cur[p] < idx[p].len() && idx[p][cur[p]] == c_min {
                    cur[p] += 1;
                    pend[p][pn[p] as usize] = c_min;
                    pn[p] += 1;
                    if pn[p] == 8 {
                        flush8(p, &pend[p]);
                        pn[p] = 0;
                    }
                }
            }
        }
        for p in 0..n_pos {
            for j in 0..pn[p] as usize {
                flush1(p, pend[p][j]);
            }
            pn[p] = 0;
        }
    });
}

/// Union merge-walk over one row window `[row0, row0+rows)`.
///
/// # Safety
/// The windows `out_base[p*out_stride + row0 .. + rows]` must be valid for
/// writes and disjoint from every other live reference for all
/// `p < idx.len()` (they are: positions occupy disjoint strided rows, and
/// the parallel driver hands each worker a disjoint row window).
unsafe fn walk_rows_batch(
    backend: Backend,
    w: &ColMajorMatrix,
    xs: &[f32],
    in_stride: usize,
    idx: &[Vec<u32>],
    out_base: *mut f32,
    out_stride: usize,
    row0: usize,
    rows: usize,
) {
    let m = w.m;
    let window = |p: usize| unsafe {
        std::slice::from_raw_parts_mut(out_base.add(p * out_stride + row0), rows)
    };
    for p in 0..idx.len() {
        window(p).fill(0.0);
    }
    let mut coeffs = [0.0f32; 8];
    let mut offs = [0usize; 8];
    merge_walk_groups(
        idx,
        |p, cols| {
            let x = &xs[p * in_stride..];
            for (j, &c) in cols.iter().enumerate() {
                let c = c as usize;
                coeffs[j] = x[c];
                offs[j] = c * m + row0;
            }
            simd::axpy8_with(backend, &coeffs, &offs, &w.data, window(p));
        },
        |p, c| {
            let c = c as usize;
            let lo = c * m + row0;
            simd::axpy_with(backend, xs[p * in_stride + c], &w.data[lo..lo + rows], window(p));
        },
    );
}

/// Batch-fused scored/threshold projection on the process-wide backend with
/// the production split threshold. Writes each position's kept count into
/// `kept_out`; returns the union (distinct streamed) column count.
#[allow(clippy::too_many_arguments)]
pub fn sparse_gemv_masked_batch(
    w: &ColMajorMatrix,
    xs: &[f32],
    in_stride: usize,
    ga: Option<&[f32]>,
    tau: f32,
    outs: &mut [f32],
    out_stride: usize,
    n_pos: usize,
    kept_out: &mut [usize],
    threads: usize,
) -> usize {
    sparse_gemv_masked_batch_with(
        simd::active(),
        w,
        xs,
        in_stride,
        ga,
        tau,
        outs,
        out_stride,
        n_pos,
        kept_out,
        threads,
        PAR_MIN_MACS,
    )
}

/// As [`sparse_gemv_masked_batch`] with explicit backend and split
/// threshold. A shared `tau`/`ga` applies to every position (the engine
/// fuses only positions under the same layer plan; per-sequence plans that
/// differ fall back to per-position projection upstream).
#[allow(clippy::too_many_arguments)]
pub fn sparse_gemv_masked_batch_with(
    backend: Backend,
    w: &ColMajorMatrix,
    xs: &[f32],
    in_stride: usize,
    ga: Option<&[f32]>,
    tau: f32,
    outs: &mut [f32],
    out_stride: usize,
    n_pos: usize,
    kept_out: &mut [usize],
    threads: usize,
    min_macs: usize,
) -> usize {
    debug_assert!(n_pos >= 1);
    debug_assert!(in_stride >= w.n && out_stride >= w.m);
    debug_assert!(xs.len() >= (n_pos - 1) * in_stride + w.n);
    debug_assert!(outs.len() >= (n_pos - 1) * out_stride + w.m);
    debug_assert!(kept_out.len() >= n_pos);
    with_scanned_batch(
        n_pos,
        w.n,
        |p, l| {
            let x = &xs[p * in_stride..p * in_stride + w.n];
            match ga {
                Some(ga) => {
                    debug_assert_eq!(ga.len(), w.n);
                    simd::scan_scored_with(backend, x, ga, tau, l);
                }
                None => simd::scan_threshold_with(backend, x, tau, l),
            }
            kept_out[p] = l.len();
        },
        |idx| {
        let union = union_count(idx);
        let base = SendPtr(outs.as_mut_ptr());
        if threads <= 1 || w.m.saturating_mul(union) < min_macs.max(1) {
            // Safety: `outs` is exclusively borrowed; the serial walk is the
            // only writer.
            unsafe {
                walk_rows_batch(backend, w, xs, in_stride, idx, base.0, out_stride, 0, w.m)
            };
            return union;
        }
        // AXPY accumulation is elementwise over output rows, so any aligned
        // row split is bit-identical to the serial walk.
        parallel_row_windows(w.m, threads, 8, |row0, rows| {
            let b = base;
            // Safety: workers receive disjoint row windows; within a worker
            // positions occupy disjoint strided rows.
            unsafe {
                walk_rows_batch(backend, w, xs, in_stride, idx, b.0, out_stride, row0, rows)
            };
        });
        union
    })
}

/// Dense row window accumulation for a strided batch: every column, eight at
/// a time, per position — per-position op order identical to `dense_rows`,
/// while the just-touched weight group stays cache-hot across positions.
///
/// # Safety
/// Same disjoint-window contract as [`walk_rows_batch`].
unsafe fn dense_rows_batch(
    backend: Backend,
    w: &ColMajorMatrix,
    xs: &[f32],
    in_stride: usize,
    n_pos: usize,
    out_base: *mut f32,
    out_stride: usize,
    row0: usize,
    rows: usize,
) {
    let m = w.m;
    let n = w.n;
    let window = |p: usize| unsafe {
        std::slice::from_raw_parts_mut(out_base.add(p * out_stride + row0), rows)
    };
    for p in 0..n_pos {
        window(p).fill(0.0);
    }
    let mut coeffs = [0.0f32; 8];
    let mut offs = [0usize; 8];
    let mut c = 0usize;
    while c + 8 <= n {
        for (j, off) in offs.iter_mut().enumerate() {
            *off = (c + j) * m + row0;
        }
        for p in 0..n_pos {
            let x = &xs[p * in_stride..];
            for (j, coeff) in coeffs.iter_mut().enumerate() {
                *coeff = x[c + j];
            }
            simd::axpy8_with(backend, &coeffs, &offs, &w.data, window(p));
        }
        c += 8;
    }
    while c < n {
        let lo = c * m + row0;
        for p in 0..n_pos {
            simd::axpy_with(backend, xs[p * in_stride + c], &w.data[lo..lo + rows], window(p));
        }
        c += 1;
    }
}

/// Dense batch projection (the fused `lm_head` path): all channels for every
/// position, one pass over the weight columns. Returns `w.n`.
pub fn dense_gemv_batch(
    w: &ColMajorMatrix,
    xs: &[f32],
    in_stride: usize,
    outs: &mut [f32],
    out_stride: usize,
    n_pos: usize,
    threads: usize,
) -> usize {
    dense_gemv_batch_with(
        simd::active(),
        w,
        xs,
        in_stride,
        outs,
        out_stride,
        n_pos,
        threads,
        PAR_MIN_MACS,
    )
}

/// As [`dense_gemv_batch`] with explicit backend and split threshold.
#[allow(clippy::too_many_arguments)]
pub fn dense_gemv_batch_with(
    backend: Backend,
    w: &ColMajorMatrix,
    xs: &[f32],
    in_stride: usize,
    outs: &mut [f32],
    out_stride: usize,
    n_pos: usize,
    threads: usize,
    min_macs: usize,
) -> usize {
    debug_assert!(n_pos >= 1);
    debug_assert!(in_stride >= w.n && out_stride >= w.m);
    debug_assert!(xs.len() >= (n_pos - 1) * in_stride + w.n);
    debug_assert!(outs.len() >= (n_pos - 1) * out_stride + w.m);
    let base = SendPtr(outs.as_mut_ptr());
    if threads <= 1 || w.m.saturating_mul(w.n) < min_macs.max(1) {
        // Safety: `outs` is exclusively borrowed; serial walk only writer.
        unsafe {
            dense_rows_batch(backend, w, xs, in_stride, n_pos, base.0, out_stride, 0, w.m)
        };
        return w.n;
    }
    parallel_row_windows(w.m, threads, 8, |row0, rows| {
        let b = base;
        // Safety: disjoint row windows per worker, disjoint strided rows
        // per position.
        unsafe {
            dense_rows_batch(backend, w, xs, in_stride, n_pos, b.0, out_stride, row0, rows)
        };
    });
    w.n
}

/// Count of channels a scored mask keeps (no compute) — used by FLOP
/// accounting dry-runs and tests.
pub fn count_kept_scored(x: &[f32], ga: &[f32], tau: f32) -> usize {
    x.iter()
        .zip(ga)
        .filter(|(&xv, &g)| xv.abs() * g >= tau)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_xwt;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    fn setup(m: usize, n: usize, seed: u64) -> (Tensor, ColMajorMatrix, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let w = Tensor::randn(&[m, n], 1.0, &mut rng);
        let cm = ColMajorMatrix::from_row_major(&w);
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        (w, cm, x)
    }

    #[test]
    fn dense_matches_reference_matmul() {
        let (w, cm, x) = setup(17, 23, 31);
        let mut out = vec![0.0f32; 17];
        let kept = dense_gemv(&cm, &x, &mut out);
        assert_eq!(kept, 23);
        let xr = Tensor::from_vec(&[1, 23], x.clone());
        let expect = matmul_xwt(&xr, &w);
        for i in 0..17 {
            assert!((out[i] - expect.data[i]).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn scored_with_zero_tau_keeps_all() {
        let (_, cm, x) = setup(8, 12, 5);
        let ga = vec![1.0f32; 12];
        let mut dense = vec![0.0f32; 8];
        let mut scored = vec![0.0f32; 8];
        dense_gemv(&cm, &x, &mut dense);
        let kept = sparse_gemv_scored(&cm, &x, &ga, 0.0, &mut scored);
        assert_eq!(kept, 12);
        assert_eq!(dense, scored);
    }

    #[test]
    fn scored_equals_masked_reference() {
        let (w, cm, x) = setup(10, 20, 7);
        let mut rng = Pcg64::new(99);
        let ga: Vec<f32> = (0..20).map(|_| rng.next_f32() + 0.1).collect();
        let tau = 0.5f32;
        // Reference: zero masked channels, dense matmul.
        let masked: Vec<f32> = x
            .iter()
            .zip(&ga)
            .map(|(&xv, &g)| if xv.abs() * g >= tau { xv } else { 0.0 })
            .collect();
        let expect = matmul_xwt(&Tensor::from_vec(&[1, 20], masked.clone()), &w);
        let mut out = vec![0.0f32; 10];
        let kept = sparse_gemv_scored(&cm, &x, &ga, tau, &mut out);
        assert_eq!(kept, masked.iter().filter(|&&v| v != 0.0).count());
        for i in 0..10 {
            assert!((out[i] - expect.data[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn threshold_is_scored_with_unit_ga() {
        let (_, cm, x) = setup(6, 15, 13);
        let ga = vec![1.0f32; 15];
        let mut a = vec![0.0f32; 6];
        let mut b = vec![0.0f32; 6];
        let ka = sparse_gemv_threshold(&cm, &x, 0.7, &mut a);
        let kb = sparse_gemv_scored(&cm, &x, &ga, 0.7, &mut b);
        assert_eq!(ka, kb);
        assert_eq!(a, b);
    }

    #[test]
    fn indices_variant_matches() {
        let (_, cm, x) = setup(9, 14, 17);
        let channels: Vec<usize> = vec![0, 3, 7, 13];
        let mut by_idx = vec![0.0f32; 9];
        sparse_gemv_indices(&cm, &x, &channels, &mut by_idx);
        // Equivalent dense with zeroed complement.
        let mut xz = vec![0.0f32; 14];
        for &c in &channels {
            xz[c] = x[c];
        }
        let mut by_dense = vec![0.0f32; 9];
        dense_gemv(&cm, &xz, &mut by_dense);
        for i in 0..9 {
            assert!((by_idx[i] - by_dense[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn collect_reports_kept_channels() {
        let (_, cm, x) = setup(4, 10, 19);
        let ga = vec![1.0f32; 10];
        let mut out = vec![0.0f32; 4];
        let mut kept = Vec::new();
        sparse_gemv_scored_collect(&cm, &x, &ga, 0.4, &mut out, &mut kept);
        for &c in &kept {
            assert!(x[c].abs() >= 0.4);
        }
        for c in 0..10 {
            if !kept.contains(&c) {
                assert!(x[c].abs() < 0.4);
            }
        }
        assert_eq!(kept.len(), count_kept_scored(&x, &ga, 0.4));
    }

    #[test]
    fn x4_variant_matches_scalar() {
        for seed in [3u64, 7, 11, 13] {
            let (_, cm, x) = setup(23, 37, seed);
            let mut rng = Pcg64::new(seed ^ 0xF0);
            let ga: Vec<f32> = (0..37).map(|_| rng.next_f32() + 0.05).collect();
            for tau in [0.0f32, 0.2, 0.6, 1.4, f32::INFINITY] {
                let mut a = vec![0.0f32; 23];
                let mut b = vec![0.0f32; 23];
                let ka = sparse_gemv_scored(&cm, &x, &ga, tau, &mut a);
                let kb = sparse_gemv_scored_x4(&cm, &x, &ga, tau, &mut b);
                assert_eq!(ka, kb, "tau {tau}");
                for i in 0..23 {
                    assert!((a[i] - b[i]).abs() < 1e-4, "tau {tau} row {i}");
                }
            }
        }
    }

    #[test]
    fn fused_matches_scalar_scored_and_threshold() {
        for seed in [2u64, 5, 9] {
            // Odd dims on purpose: exercise the SIMD remainders.
            let (_, cm, x) = setup(29, 41, seed);
            let mut rng = Pcg64::new(seed ^ 0xAB);
            let ga: Vec<f32> = (0..41).map(|_| rng.next_f32() + 0.05).collect();
            let mut kept_idx = Vec::new();
            for tau in [0.0f32, 0.3, 0.9, f32::INFINITY] {
                let mut a = vec![0.0f32; 29];
                let mut b = vec![0.0f32; 29];
                let ka = sparse_gemv_scored(&cm, &x, &ga, tau, &mut a);
                let kb = sparse_gemv_fused(&cm, &x, Some(&ga), tau, &mut b, &mut kept_idx);
                assert_eq!(ka, kb, "scored tau {tau}");
                for i in 0..29 {
                    assert!((a[i] - b[i]).abs() < 1e-4, "scored tau {tau} row {i}");
                }
                let ka = sparse_gemv_threshold(&cm, &x, tau, &mut a);
                let kb = sparse_gemv_fused(&cm, &x, None, tau, &mut b, &mut kept_idx);
                assert_eq!(ka, kb, "threshold tau {tau}");
                for i in 0..29 {
                    assert!((a[i] - b[i]).abs() < 1e-4, "threshold tau {tau} row {i}");
                }
            }
        }
    }

    #[test]
    fn fused_parallel_split_is_bit_identical_to_serial() {
        let (_, cm, x) = setup(53, 31, 71);
        let mut rng = Pcg64::new(0x17);
        let ga: Vec<f32> = (0..31).map(|_| rng.next_f32() + 0.05).collect();
        let mut kept_idx = Vec::new();
        let mut serial = vec![0.0f32; 53];
        let ks = sparse_gemv_fused(&cm, &x, Some(&ga), 0.4, &mut serial, &mut kept_idx);
        for threads in [2usize, 3, 8] {
            let mut par = vec![0.0f32; 53];
            // min_macs = 0 forces the row split even on this tiny shape.
            let kp = sparse_gemv_fused_parallel_with(
                crate::sparse_kernel::simd::active(),
                &cm,
                &x,
                Some(&ga),
                0.4,
                &mut par,
                &mut kept_idx,
                threads,
                0,
            );
            assert_eq!(ks, kp);
            assert_eq!(serial, par, "threads {threads}");
        }
    }

    #[test]
    fn dense_simd_and_parallel_match_reference() {
        let (_, cm, x) = setup(27, 19, 83);
        let mut reference = vec![0.0f32; 27];
        dense_gemv(&cm, &x, &mut reference);
        for backend in crate::sparse_kernel::simd::available_backends() {
            let mut out = vec![0.0f32; 27];
            assert_eq!(dense_gemv_simd_with(backend, &cm, &x, &mut out), 19);
            for i in 0..27 {
                assert!((out[i] - reference[i]).abs() < 1e-4, "{} row {i}", backend.name());
            }
        }
        let mut out = vec![1.0f32; 27];
        assert_eq!(dense_gemv_parallel(&cm, &x, &mut out, 4), 19);
        for i in 0..27 {
            assert!((out[i] - reference[i]).abs() < 1e-4, "parallel row {i}");
        }
    }

    #[test]
    fn infinite_tau_keeps_nothing() {
        let (_, cm, x) = setup(5, 8, 23);
        let ga = vec![1.0f32; 8];
        let mut out = vec![1.0f32; 5];
        let kept = sparse_gemv_scored(&cm, &x, &ga, f32::INFINITY, &mut out);
        assert_eq!(kept, 0);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    /// Strided batch of `n_pos` activation rows with a padded stride so the
    /// stride-handling paths get exercised, not just the compact layout.
    fn batch_setup(m: usize, n: usize, n_pos: usize, seed: u64) -> (ColMajorMatrix, Vec<f32>, usize) {
        let mut rng = Pcg64::new(seed);
        let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 1.0, &mut rng));
        let in_stride = n + 3;
        let mut xs = vec![f32::NAN; n_pos * in_stride];
        for p in 0..n_pos {
            for c in 0..n {
                xs[p * in_stride + c] = rng.normal() as f32;
            }
        }
        (w, xs, in_stride)
    }

    #[test]
    fn masked_batch_bit_identical_to_per_position() {
        let (m, n, n_pos) = (29usize, 41usize, 5usize);
        for seed in [3u64, 11] {
            let (cm, xs, in_stride) = batch_setup(m, n, n_pos, seed);
            let mut rng = Pcg64::new(seed ^ 0xC0);
            let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.05).collect();
            let backend = crate::sparse_kernel::simd::active();
            for ga_opt in [Some(ga.as_slice()), None] {
                // INFINITY: keep-nothing masks in a batch still zero their rows.
                for tau in [0.0f32, 0.3, 0.9, f32::INFINITY] {
                    let out_stride = m + 5;
                    let mut refs = vec![0.0f32; n_pos * m];
                    let mut kept_ref = vec![0usize; n_pos];
                    let mut idx = Vec::new();
                    for p in 0..n_pos {
                        kept_ref[p] = sparse_gemv_fused_with(
                            backend,
                            &cm,
                            &xs[p * in_stride..p * in_stride + n],
                            ga_opt,
                            tau,
                            &mut refs[p * m..(p + 1) * m],
                            &mut idx,
                        );
                    }
                    for threads in [1usize, 3] {
                        let mut outs = vec![f32::NAN; n_pos * out_stride];
                        let mut kept = vec![0usize; n_pos];
                        // min_macs = 0 forces the row split at threads > 1.
                        let union = sparse_gemv_masked_batch_with(
                            backend, &cm, &xs, in_stride, ga_opt, tau, &mut outs,
                            out_stride, n_pos, &mut kept, threads, 0,
                        );
                        assert_eq!(kept, kept_ref, "tau {tau} threads {threads}");
                        assert!(union <= kept.iter().sum::<usize>().max(n));
                        assert!(union >= kept.iter().copied().max().unwrap_or(0));
                        for p in 0..n_pos {
                            for i in 0..m {
                                assert_eq!(
                                    outs[p * out_stride + i].to_bits(),
                                    refs[p * m + i].to_bits(),
                                    "tau {tau} threads {threads} pos {p} row {i}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn masked_batch_of_one_matches_single_kernel() {
        let (cm, xs, in_stride) = batch_setup(23, 17, 1, 9);
        let mut idx = Vec::new();
        let mut single = vec![0.0f32; 23];
        let backend = crate::sparse_kernel::simd::active();
        let ks = sparse_gemv_fused_with(backend, &cm, &xs[..17], None, 0.4, &mut single, &mut idx);
        let mut outs = vec![0.0f32; 23];
        let mut kept = [0usize; 1];
        let union = sparse_gemv_masked_batch_with(
            backend, &cm, &xs, in_stride, None, 0.4, &mut outs, 23, 1, &mut kept, 1, 0,
        );
        assert_eq!((union, kept[0]), (ks, ks));
        for i in 0..23 {
            assert_eq!(outs[i].to_bits(), single[i].to_bits(), "row {i}");
        }
    }

    #[test]
    fn dense_batch_bit_identical_to_per_position() {
        let (m, n, n_pos) = (27usize, 19usize, 4usize);
        let (cm, xs, in_stride) = batch_setup(m, n, n_pos, 83);
        let backend = crate::sparse_kernel::simd::active();
        let mut refs = vec![0.0f32; n_pos * m];
        for p in 0..n_pos {
            dense_gemv_simd_with(
                backend,
                &cm,
                &xs[p * in_stride..p * in_stride + n],
                &mut refs[p * m..(p + 1) * m],
            );
        }
        for threads in [1usize, 4] {
            let mut outs = vec![f32::NAN; n_pos * m];
            let streamed = dense_gemv_batch_with(
                backend, &cm, &xs, in_stride, &mut outs, m, n_pos, threads, 0,
            );
            assert_eq!(streamed, n);
            for i in 0..n_pos * m {
                assert_eq!(outs[i].to_bits(), refs[i].to_bits(), "threads {threads} idx {i}");
            }
        }
    }
}
