//! Channel-skipping GEMV kernels.
//!
//! All kernels compute `y = x_S (W[:,S])^T` (Eq. 3) for different ways of
//! choosing `S`, and return `|S|` so the engine can account actual FLOPs.
//! They accumulate with a single pass over kept channels; each kept channel
//! contributes one contiguous AXPY over the output vector, which the
//! compiler auto-vectorizes.

use super::layout::ColMajorMatrix;
use super::simd::{self, Backend};
use crate::util::threadpool::parallel_slices_aligned;

/// Minimum multiply-accumulates before intra-GEMV row parallelism pays for
/// its thread fork-join. Below this the fused kernels run on the calling
/// thread (micro/nano model shapes never split; `lm_head`-sized projections
/// on real vocabularies do).
pub const PAR_MIN_MACS: usize = 1 << 21;

/// Dense projection (S = all channels). Baseline for the speedup plots.
pub fn dense_gemv(w: &ColMajorMatrix, x: &[f32], out: &mut [f32]) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    for (c, &xv) in x.iter().enumerate() {
        axpy(xv, w.col(c), out);
    }
    w.n
}

/// WiSparse / WINA scored projection: keep channel c iff
/// `|x_c| * ga_c >= tau`, where `ga_c = g_c^alpha` is precomputed (Eq. 4-5).
/// Scoring is fused into the accumulation pass — the per-channel overhead is
/// one abs, one multiply and one compare, matching the paper's "negligible
/// overhead" claim.
pub fn sparse_gemv_scored(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: &[f32],
    tau: f32,
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(ga.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    let mut kept = 0usize;
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() * ga[c] >= tau {
            axpy(xv, w.col(c), out);
            kept += 1;
        }
    }
    kept
}

/// TEAL-style magnitude thresholding: keep iff `|x_c| >= tau`.
pub fn sparse_gemv_threshold(
    w: &ColMajorMatrix,
    x: &[f32],
    tau: f32,
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    let mut kept = 0usize;
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() >= tau {
            axpy(xv, w.col(c), out);
            kept += 1;
        }
    }
    kept
}

/// Projection over an explicit channel index set (R-Sparse's top-k path,
/// and the generic fallback).
pub fn sparse_gemv_indices(
    w: &ColMajorMatrix,
    x: &[f32],
    channels: &[usize],
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    for &c in channels {
        axpy(x[c], w.col(c), out);
    }
    channels.len()
}

/// Scored projection that additionally writes the kept-channel indices into
/// `kept_buf` (used by R-Sparse to route the complement through the low-rank
/// path, and by diagnostics).
pub fn sparse_gemv_scored_collect(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: &[f32],
    tau: f32,
    out: &mut [f32],
    kept_buf: &mut Vec<usize>,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(ga.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    kept_buf.clear();
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() * ga[c] >= tau {
            axpy(xv, w.col(c), out);
            kept_buf.push(c);
        }
    }
    kept_buf.len()
}

/// out += a * col. The single hot loop of the engine; kept free of bounds
/// checks via exact-length slices so LLVM vectorizes it.
#[inline]
pub fn axpy(a: f32, col: &[f32], out: &mut [f32]) {
    if a == 0.0 {
        return;
    }
    let n = out.len();
    debug_assert_eq!(col.len(), n);
    let (col, out) = (&col[..n], &mut out[..n]);
    for i in 0..n {
        out[i] += a * col[i];
    }
}

/// Scored projection with 4-column fused accumulation (§Perf optimization):
/// kept channels are batched in groups of four so the output vector is
/// loaded/stored once per four AXPYs instead of once per AXPY, quartering
/// the dominant store traffic of the skinny-GEMV regime.
pub fn sparse_gemv_scored_x4(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: &[f32],
    tau: f32,
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(ga.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    let m = w.m;
    let mut kept = 0usize;
    // Pending (coefficient, column offset) pairs awaiting a fused flush.
    let mut coeffs = [0.0f32; 4];
    let mut offs = [0usize; 4];
    let mut pending = 0usize;
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() * ga[c] >= tau {
            coeffs[pending] = xv;
            offs[pending] = c * m;
            pending += 1;
            kept += 1;
            if pending == 4 {
                axpy4(&coeffs, &offs, &w.data, out);
                pending = 0;
            }
        }
    }
    for p in 0..pending {
        axpy(coeffs[p], &w.data[offs[p]..offs[p] + m], out);
    }
    kept
}

/// out += sum_j coeffs[j] * data[offs[j]..offs[j]+m]. All four columns are
/// walked in lockstep; LLVM vectorizes the inner loop into FMA chains.
#[inline]
fn axpy4(coeffs: &[f32; 4], offs: &[usize; 4], data: &[f32], out: &mut [f32]) {
    let m = out.len();
    let (a0, a1, a2, a3) = (coeffs[0], coeffs[1], coeffs[2], coeffs[3]);
    let c0 = &data[offs[0]..offs[0] + m];
    let c1 = &data[offs[1]..offs[1] + m];
    let c2 = &data[offs[2]..offs[2] + m];
    let c3 = &data[offs[3]..offs[3] + m];
    for i in 0..m {
        out[i] += a0 * c0[i] + a1 * c1[i] + a2 * c2[i] + a3 * c3[i];
    }
}

// ---------------------------------------------------------------------------
// Two-pass fused kernels (SIMD backend, §Tentpole): pass 1 scans the mask
// predicate into a reusable index buffer, pass 2 accumulates kept columns in
// fused groups of eight so the output vector is loaded/stored once per eight
// AXPYs. `ga = None` is the TEAL/magnitude path — it gets the same fused
// treatment, which the single-pass kernels above never gave it.
// ---------------------------------------------------------------------------

/// Fused scored/threshold projection on the process-wide SIMD backend.
/// `kept_idx` is caller-owned scratch (no allocation once warm).
pub fn sparse_gemv_fused(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
) -> usize {
    sparse_gemv_fused_with(simd::active(), w, x, ga, tau, out, kept_idx)
}

/// Fused projection on an explicit backend (tests / bench sweeps).
pub fn sparse_gemv_fused_with(
    backend: Backend,
    w: &ColMajorMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    match ga {
        Some(ga) => {
            debug_assert_eq!(ga.len(), w.n);
            simd::scan_scored_with(backend, x, ga, tau, kept_idx);
        }
        None => simd::scan_threshold_with(backend, x, tau, kept_idx),
    }
    out.fill(0.0);
    accum_rows(backend, w, x, kept_idx, 0, out);
    kept_idx.len()
}

/// Fused projection with intra-GEMV row parallelism: when the kept work is
/// large enough (`PAR_MIN_MACS`), the output range is split into contiguous
/// row windows across `threads`, each walking the same kept-index list over
/// its own column sub-slices. Window boundaries are aligned to the SIMD
/// group width, so every element lands in the same vector-body/scalar-tail
/// position as in the serial kernel and the result is bit-identical at any
/// thread count.
pub fn sparse_gemv_fused_parallel(
    w: &ColMajorMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
    threads: usize,
) -> usize {
    sparse_gemv_fused_parallel_with(
        simd::active(),
        w,
        x,
        ga,
        tau,
        out,
        kept_idx,
        threads,
        PAR_MIN_MACS,
    )
}

/// As [`sparse_gemv_fused_parallel`] with explicit backend and split
/// threshold (tests force `min_macs = 0` to exercise the split path on
/// small shapes).
#[allow(clippy::too_many_arguments)]
pub fn sparse_gemv_fused_parallel_with(
    backend: Backend,
    w: &ColMajorMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
    threads: usize,
    min_macs: usize,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    match ga {
        Some(ga) => {
            debug_assert_eq!(ga.len(), w.n);
            simd::scan_scored_with(backend, x, ga, tau, kept_idx);
        }
        None => simd::scan_threshold_with(backend, x, tau, kept_idx),
    }
    let kept = kept_idx.len();
    if threads <= 1 || w.m.saturating_mul(kept) < min_macs.max(1) {
        out.fill(0.0);
        accum_rows(backend, w, x, kept_idx, 0, out);
        return kept;
    }
    let idx: &[u32] = kept_idx.as_slice();
    parallel_slices_aligned(out, threads, 8, |_, row0, rows| {
        rows.fill(0.0);
        accum_rows(backend, w, x, idx, row0, rows);
    });
    kept
}

/// Dense projection on an explicit SIMD backend (all channels kept; no scan
/// or index buffer needed).
pub fn dense_gemv_simd_with(
    backend: Backend,
    w: &ColMajorMatrix,
    x: &[f32],
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    dense_rows(backend, w, x, 0, out);
    w.n
}

/// Dense projection with intra-GEMV row parallelism — the `lm_head` path of
/// single-sequence decode, where the output dim (vocab) dwarfs every other
/// projection.
pub fn dense_gemv_parallel(
    w: &ColMajorMatrix,
    x: &[f32],
    out: &mut [f32],
    threads: usize,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    let backend = simd::active();
    if threads <= 1 || w.m.saturating_mul(w.n) < PAR_MIN_MACS {
        out.fill(0.0);
        dense_rows(backend, w, x, 0, out);
        return w.n;
    }
    parallel_slices_aligned(out, threads, 8, |_, row0, rows| {
        rows.fill(0.0);
        dense_rows(backend, w, x, row0, rows);
    });
    w.n
}

/// rows += sum over kept channels of `x[c] * W[row0..row0+rows.len(), c]`,
/// fused eight columns at a time.
fn accum_rows(
    backend: Backend,
    w: &ColMajorMatrix,
    x: &[f32],
    idx: &[u32],
    row0: usize,
    rows: &mut [f32],
) {
    let m = w.m;
    debug_assert!(row0 + rows.len() <= m);
    let mut coeffs = [0.0f32; 8];
    let mut offs = [0usize; 8];
    let groups = idx.chunks_exact(8);
    let rem = groups.remainder();
    for group in groups {
        for (j, &c) in group.iter().enumerate() {
            let c = c as usize;
            coeffs[j] = x[c];
            offs[j] = c * m + row0;
        }
        simd::axpy8_with(backend, &coeffs, &offs, &w.data, rows);
    }
    for &c in rem {
        let c = c as usize;
        let lo = c * m + row0;
        simd::axpy_with(backend, x[c], &w.data[lo..lo + rows.len()], rows);
    }
}

/// rows += `x W[row0..row0+rows.len(), :]^T` over every channel, fused eight
/// columns at a time (dense counterpart of [`accum_rows`]).
fn dense_rows(backend: Backend, w: &ColMajorMatrix, x: &[f32], row0: usize, rows: &mut [f32]) {
    let m = w.m;
    let n = w.n;
    debug_assert!(row0 + rows.len() <= m);
    let mut coeffs = [0.0f32; 8];
    let mut offs = [0usize; 8];
    let mut c = 0usize;
    while c + 8 <= n {
        for j in 0..8 {
            coeffs[j] = x[c + j];
            offs[j] = (c + j) * m + row0;
        }
        simd::axpy8_with(backend, &coeffs, &offs, &w.data, rows);
        c += 8;
    }
    while c < n {
        let lo = c * m + row0;
        simd::axpy_with(backend, x[c], &w.data[lo..lo + rows.len()], rows);
        c += 1;
    }
}

/// Count of channels a scored mask keeps (no compute) — used by FLOP
/// accounting dry-runs and tests.
pub fn count_kept_scored(x: &[f32], ga: &[f32], tau: f32) -> usize {
    x.iter()
        .zip(ga)
        .filter(|(&xv, &g)| xv.abs() * g >= tau)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_xwt;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    fn setup(m: usize, n: usize, seed: u64) -> (Tensor, ColMajorMatrix, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let w = Tensor::randn(&[m, n], 1.0, &mut rng);
        let cm = ColMajorMatrix::from_row_major(&w);
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        (w, cm, x)
    }

    #[test]
    fn dense_matches_reference_matmul() {
        let (w, cm, x) = setup(17, 23, 31);
        let mut out = vec![0.0f32; 17];
        let kept = dense_gemv(&cm, &x, &mut out);
        assert_eq!(kept, 23);
        let xr = Tensor::from_vec(&[1, 23], x.clone());
        let expect = matmul_xwt(&xr, &w);
        for i in 0..17 {
            assert!((out[i] - expect.data[i]).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn scored_with_zero_tau_keeps_all() {
        let (_, cm, x) = setup(8, 12, 5);
        let ga = vec![1.0f32; 12];
        let mut dense = vec![0.0f32; 8];
        let mut scored = vec![0.0f32; 8];
        dense_gemv(&cm, &x, &mut dense);
        let kept = sparse_gemv_scored(&cm, &x, &ga, 0.0, &mut scored);
        assert_eq!(kept, 12);
        assert_eq!(dense, scored);
    }

    #[test]
    fn scored_equals_masked_reference() {
        let (w, cm, x) = setup(10, 20, 7);
        let mut rng = Pcg64::new(99);
        let ga: Vec<f32> = (0..20).map(|_| rng.next_f32() + 0.1).collect();
        let tau = 0.5f32;
        // Reference: zero masked channels, dense matmul.
        let masked: Vec<f32> = x
            .iter()
            .zip(&ga)
            .map(|(&xv, &g)| if xv.abs() * g >= tau { xv } else { 0.0 })
            .collect();
        let expect = matmul_xwt(&Tensor::from_vec(&[1, 20], masked.clone()), &w);
        let mut out = vec![0.0f32; 10];
        let kept = sparse_gemv_scored(&cm, &x, &ga, tau, &mut out);
        assert_eq!(kept, masked.iter().filter(|&&v| v != 0.0).count());
        for i in 0..10 {
            assert!((out[i] - expect.data[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn threshold_is_scored_with_unit_ga() {
        let (_, cm, x) = setup(6, 15, 13);
        let ga = vec![1.0f32; 15];
        let mut a = vec![0.0f32; 6];
        let mut b = vec![0.0f32; 6];
        let ka = sparse_gemv_threshold(&cm, &x, 0.7, &mut a);
        let kb = sparse_gemv_scored(&cm, &x, &ga, 0.7, &mut b);
        assert_eq!(ka, kb);
        assert_eq!(a, b);
    }

    #[test]
    fn indices_variant_matches() {
        let (_, cm, x) = setup(9, 14, 17);
        let channels: Vec<usize> = vec![0, 3, 7, 13];
        let mut by_idx = vec![0.0f32; 9];
        sparse_gemv_indices(&cm, &x, &channels, &mut by_idx);
        // Equivalent dense with zeroed complement.
        let mut xz = vec![0.0f32; 14];
        for &c in &channels {
            xz[c] = x[c];
        }
        let mut by_dense = vec![0.0f32; 9];
        dense_gemv(&cm, &xz, &mut by_dense);
        for i in 0..9 {
            assert!((by_idx[i] - by_dense[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn collect_reports_kept_channels() {
        let (_, cm, x) = setup(4, 10, 19);
        let ga = vec![1.0f32; 10];
        let mut out = vec![0.0f32; 4];
        let mut kept = Vec::new();
        sparse_gemv_scored_collect(&cm, &x, &ga, 0.4, &mut out, &mut kept);
        for &c in &kept {
            assert!(x[c].abs() >= 0.4);
        }
        for c in 0..10 {
            if !kept.contains(&c) {
                assert!(x[c].abs() < 0.4);
            }
        }
        assert_eq!(kept.len(), count_kept_scored(&x, &ga, 0.4));
    }

    #[test]
    fn x4_variant_matches_scalar() {
        for seed in [3u64, 7, 11, 13] {
            let (_, cm, x) = setup(23, 37, seed);
            let mut rng = Pcg64::new(seed ^ 0xF0);
            let ga: Vec<f32> = (0..37).map(|_| rng.next_f32() + 0.05).collect();
            for tau in [0.0f32, 0.2, 0.6, 1.4, f32::INFINITY] {
                let mut a = vec![0.0f32; 23];
                let mut b = vec![0.0f32; 23];
                let ka = sparse_gemv_scored(&cm, &x, &ga, tau, &mut a);
                let kb = sparse_gemv_scored_x4(&cm, &x, &ga, tau, &mut b);
                assert_eq!(ka, kb, "tau {tau}");
                for i in 0..23 {
                    assert!((a[i] - b[i]).abs() < 1e-4, "tau {tau} row {i}");
                }
            }
        }
    }

    #[test]
    fn fused_matches_scalar_scored_and_threshold() {
        for seed in [2u64, 5, 9] {
            // Odd dims on purpose: exercise the SIMD remainders.
            let (_, cm, x) = setup(29, 41, seed);
            let mut rng = Pcg64::new(seed ^ 0xAB);
            let ga: Vec<f32> = (0..41).map(|_| rng.next_f32() + 0.05).collect();
            let mut kept_idx = Vec::new();
            for tau in [0.0f32, 0.3, 0.9, f32::INFINITY] {
                let mut a = vec![0.0f32; 29];
                let mut b = vec![0.0f32; 29];
                let ka = sparse_gemv_scored(&cm, &x, &ga, tau, &mut a);
                let kb = sparse_gemv_fused(&cm, &x, Some(&ga), tau, &mut b, &mut kept_idx);
                assert_eq!(ka, kb, "scored tau {tau}");
                for i in 0..29 {
                    assert!((a[i] - b[i]).abs() < 1e-4, "scored tau {tau} row {i}");
                }
                let ka = sparse_gemv_threshold(&cm, &x, tau, &mut a);
                let kb = sparse_gemv_fused(&cm, &x, None, tau, &mut b, &mut kept_idx);
                assert_eq!(ka, kb, "threshold tau {tau}");
                for i in 0..29 {
                    assert!((a[i] - b[i]).abs() < 1e-4, "threshold tau {tau} row {i}");
                }
            }
        }
    }

    #[test]
    fn fused_parallel_split_is_bit_identical_to_serial() {
        let (_, cm, x) = setup(53, 31, 71);
        let mut rng = Pcg64::new(0x17);
        let ga: Vec<f32> = (0..31).map(|_| rng.next_f32() + 0.05).collect();
        let mut kept_idx = Vec::new();
        let mut serial = vec![0.0f32; 53];
        let ks = sparse_gemv_fused(&cm, &x, Some(&ga), 0.4, &mut serial, &mut kept_idx);
        for threads in [2usize, 3, 8] {
            let mut par = vec![0.0f32; 53];
            // min_macs = 0 forces the row split even on this tiny shape.
            let kp = sparse_gemv_fused_parallel_with(
                crate::sparse_kernel::simd::active(),
                &cm,
                &x,
                Some(&ga),
                0.4,
                &mut par,
                &mut kept_idx,
                threads,
                0,
            );
            assert_eq!(ks, kp);
            assert_eq!(serial, par, "threads {threads}");
        }
    }

    #[test]
    fn dense_simd_and_parallel_match_reference() {
        let (_, cm, x) = setup(27, 19, 83);
        let mut reference = vec![0.0f32; 27];
        dense_gemv(&cm, &x, &mut reference);
        for backend in crate::sparse_kernel::simd::available_backends() {
            let mut out = vec![0.0f32; 27];
            assert_eq!(dense_gemv_simd_with(backend, &cm, &x, &mut out), 19);
            for i in 0..27 {
                assert!((out[i] - reference[i]).abs() < 1e-4, "{} row {i}", backend.name());
            }
        }
        let mut out = vec![1.0f32; 27];
        assert_eq!(dense_gemv_parallel(&cm, &x, &mut out, 4), 19);
        for i in 0..27 {
            assert!((out[i] - reference[i]).abs() < 1e-4, "parallel row {i}");
        }
    }

    #[test]
    fn infinite_tau_keeps_nothing() {
        let (_, cm, x) = setup(5, 8, 23);
        let ga = vec![1.0f32; 8];
        let mut out = vec![1.0f32; 5];
        let kept = sparse_gemv_scored(&cm, &x, &ga, f32::INFINITY, &mut out);
        assert_eq!(kept, 0);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
