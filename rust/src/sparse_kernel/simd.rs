//! Runtime-dispatched SIMD primitives for the sparse GEMV hot loops.
//!
//! Three operations cover every hot path in the engine: `axpy` (one kept
//! column into the accumulator), `axpy8` (eight kept columns fused into one
//! load/store pass over the accumulator, which is what makes the skinny-GEMV
//! regime memory-efficient), and the scored mask scans that turn
//! `|x_c| * ga_c >= tau` into a packed index list.
//!
//! The backend is chosen once per process via [`active`]:
//!
//! - `x86_64` with AVX2+FMA detected at runtime → [`Backend::Avx2`]
//! - `aarch64` → [`Backend::Neon`]
//! - anything else, or `WISPARSE_SIMD=off` → [`Backend::Scalar`]
//!
//! The scalar implementations are the reference: every dispatched kernel is
//! property-tested against them (`rust/tests/simd_backends.rs`), and forcing
//! `WISPARSE_SIMD=off` must never change kept-channel counts — the scan
//! predicate is evaluated with identical semantics (NaN scores and
//! `tau = inf` included) on every backend.

use std::sync::OnceLock;

/// A SIMD instruction-set backend. Variants only exist on architectures
/// where the implementation can run, so dispatch is exhaustive per-target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable reference implementation (also the `WISPARSE_SIMD=off` path).
    Scalar,
    /// AVX2 + FMA, 8 lanes of f32.
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// NEON, 4 lanes of f32.
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => "neon",
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_supported() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// Best backend the running CPU supports (ignores the env override).
pub fn best_available() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_supported() {
            Backend::Avx2
        } else {
            Backend::Scalar
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Backend::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Backend::Scalar
    }
}

/// Every backend runnable on this CPU (always includes `Scalar`). Used by
/// the property tests and the kernel bench to sweep implementations.
pub fn available_backends() -> Vec<Backend> {
    let mut out = vec![Backend::Scalar];
    let best = best_available();
    if best != Backend::Scalar {
        out.push(best);
    }
    out
}

/// Resolve a `WISPARSE_SIMD` preference string to a backend. Pure function
/// so the dispatch rule is unit-testable without touching process env.
/// Matching is case-insensitive: `off|scalar|0|no|false` force the scalar
/// reference; a backend name (`avx2`, `neon`) requests it and falls back to
/// **scalar** when this CPU/arch can't run it (never silently to another
/// SIMD backend — the override is a debugging kill switch and must not
/// surprise). Only unset/empty picks [`best_available`].
pub fn choose_backend(pref: Option<&str>) -> Backend {
    let pref = pref.map(|s| s.trim().to_ascii_lowercase());
    match pref.as_deref() {
        None | Some("") => best_available(),
        Some("off") | Some("scalar") | Some("0") | Some("no") | Some("false") => Backend::Scalar,
        Some(name) => {
            #[cfg(target_arch = "x86_64")]
            {
                if name == "avx2" && avx2_supported() {
                    return Backend::Avx2;
                }
            }
            #[cfg(target_arch = "aarch64")]
            {
                if name == "neon" {
                    return Backend::Neon;
                }
            }
            // Unknown or unavailable backend: fail safe to the reference.
            let _ = name;
            Backend::Scalar
        }
    }
}

/// The process-wide backend, detected once (first call reads
/// `WISPARSE_SIMD`; later changes to the env have no effect).
pub fn active() -> Backend {
    static ACTIVE: OnceLock<Backend> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let pref = std::env::var("WISPARSE_SIMD").ok();
        choose_backend(pref.as_deref())
    })
}

// ---------------------------------------------------------------------------
// Dispatched entry points. `*_with` takes an explicit backend (tests, bench
// sweeps); the bare name uses the process-wide choice.
// ---------------------------------------------------------------------------

/// out += a * col.
#[inline]
pub fn axpy(a: f32, col: &[f32], out: &mut [f32]) {
    axpy_with(active(), a, col, out)
}

#[inline]
pub fn axpy_with(backend: Backend, a: f32, col: &[f32], out: &mut [f32]) {
    debug_assert_eq!(col.len(), out.len());
    if a == 0.0 {
        return;
    }
    match backend {
        Backend::Scalar => scalar_axpy(a, col, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only constructed after avx2_supported() passed.
        Backend::Avx2 => unsafe { avx2::axpy(a, col, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Backend::Neon => unsafe { neon::axpy(a, col, out) },
    }
}

/// out[i] += sum_j coeffs[j] * data[offs[j] + i] for i in 0..out.len().
/// The eight columns are walked in lockstep so `out` is loaded and stored
/// once per eight AXPYs. Callers guarantee `offs[j] + out.len() <= data.len()`.
#[inline]
pub fn axpy8_with(
    backend: Backend,
    coeffs: &[f32; 8],
    offs: &[usize; 8],
    data: &[f32],
    out: &mut [f32],
) {
    let m = out.len();
    for &o in offs.iter() {
        assert!(o + m <= data.len(), "axpy8 column slice out of bounds");
    }
    match backend {
        Backend::Scalar => scalar_axpy8(coeffs, offs, data, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: bounds asserted above; feature checked at construction.
        Backend::Avx2 => unsafe { avx2::axpy8(coeffs, offs, data, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: bounds asserted above; NEON is baseline on aarch64.
        Backend::Neon => unsafe { neon::axpy8(coeffs, offs, data, out) },
    }
}

/// Scan the WiSparse/WINA predicate `|x_c| * ga_c >= tau` into `idx`
/// (cleared first). Index buffer is reusable scratch: after warmup no
/// allocation happens on any steady-state call with the same `n`.
#[inline]
pub fn scan_scored(x: &[f32], ga: &[f32], tau: f32, idx: &mut Vec<u32>) {
    scan_scored_with(active(), x, ga, tau, idx)
}

#[inline]
pub fn scan_scored_with(backend: Backend, x: &[f32], ga: &[f32], tau: f32, idx: &mut Vec<u32>) {
    debug_assert_eq!(x.len(), ga.len());
    idx.clear();
    idx.reserve(x.len());
    match backend {
        Backend::Scalar => scalar_scan_scored(x, ga, tau, idx),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: feature checked at construction.
        Backend::Avx2 => unsafe { avx2::scan_scored(x, ga, tau, idx) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Backend::Neon => unsafe { neon::scan_scored(x, ga, tau, idx) },
    }
}

/// Scan the TEAL predicate `|x_c| >= tau` into `idx` (cleared first).
#[inline]
pub fn scan_threshold(x: &[f32], tau: f32, idx: &mut Vec<u32>) {
    scan_threshold_with(active(), x, tau, idx)
}

#[inline]
pub fn scan_threshold_with(backend: Backend, x: &[f32], tau: f32, idx: &mut Vec<u32>) {
    idx.clear();
    idx.reserve(x.len());
    match backend {
        Backend::Scalar => scalar_scan_threshold(x, tau, idx),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: feature checked at construction.
        Backend::Avx2 => unsafe { avx2::scan_threshold(x, tau, idx) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Backend::Neon => unsafe { neon::scan_threshold(x, tau, idx) },
    }
}

/// out[i] = scale * (codes[i] as i8 as f32) — the group-uniform inline
/// dequantization primitive of the quantized GEMV path (`quant/gemv.rs`).
/// One IEEE multiply per element, so every backend produces bit-identical
/// values; the SIMD versions only widen the 1-byte code loads.
#[inline]
pub fn dequant_i8(scale: f32, codes: &[u8], out: &mut [f32]) {
    dequant_i8_with(active(), scale, codes, out)
}

#[inline]
pub fn dequant_i8_with(backend: Backend, scale: f32, codes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    match backend {
        Backend::Scalar => scalar_dequant_i8(scale, codes, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: feature checked at construction; lengths asserted above.
        Backend::Avx2 => unsafe { avx2::dequant_i8(scale, codes, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Backend::Neon => unsafe { neon::dequant_i8(scale, codes, out) },
    }
}

// ---------------------------------------------------------------------------
// Scalar reference implementations.
// ---------------------------------------------------------------------------

fn scalar_axpy(a: f32, col: &[f32], out: &mut [f32]) {
    let n = out.len();
    let (col, out) = (&col[..n], &mut out[..n]);
    for i in 0..n {
        out[i] += a * col[i];
    }
}

fn scalar_axpy8(coeffs: &[f32; 8], offs: &[usize; 8], data: &[f32], out: &mut [f32]) {
    let m = out.len();
    let c0 = &data[offs[0]..offs[0] + m];
    let c1 = &data[offs[1]..offs[1] + m];
    let c2 = &data[offs[2]..offs[2] + m];
    let c3 = &data[offs[3]..offs[3] + m];
    let c4 = &data[offs[4]..offs[4] + m];
    let c5 = &data[offs[5]..offs[5] + m];
    let c6 = &data[offs[6]..offs[6] + m];
    let c7 = &data[offs[7]..offs[7] + m];
    for i in 0..m {
        out[i] += coeffs[0] * c0[i]
            + coeffs[1] * c1[i]
            + coeffs[2] * c2[i]
            + coeffs[3] * c3[i]
            + coeffs[4] * c4[i]
            + coeffs[5] * c5[i]
            + coeffs[6] * c6[i]
            + coeffs[7] * c7[i];
    }
}

fn scalar_dequant_i8(scale: f32, codes: &[u8], out: &mut [f32]) {
    for (o, &b) in out.iter_mut().zip(codes) {
        *o = scale * (b as i8 as f32);
    }
}

fn scalar_scan_scored(x: &[f32], ga: &[f32], tau: f32, idx: &mut Vec<u32>) {
    for (c, (&xv, &g)) in x.iter().zip(ga).enumerate() {
        if xv.abs() * g >= tau {
            idx.push(c as u32);
        }
    }
}

fn scalar_scan_threshold(x: &[f32], tau: f32, idx: &mut Vec<u32>) {
    for (c, &xv) in x.iter().enumerate() {
        if xv.abs() >= tau {
            idx.push(c as u32);
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// SAFETY: caller checked avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(a: f32, col: &[f32], out: &mut [f32]) {
        let n = out.len();
        let va = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + 8 <= n {
            let c = _mm256_loadu_ps(col.as_ptr().add(i));
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(va, c, o));
            i += 8;
        }
        while i < n {
            *out.get_unchecked_mut(i) += a * *col.get_unchecked(i);
            i += 1;
        }
    }

    /// SAFETY: caller checked avx2+fma support and that every
    /// `offs[j] + out.len() <= data.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy8(coeffs: &[f32; 8], offs: &[usize; 8], data: &[f32], out: &mut [f32]) {
        let m = out.len();
        let base = data.as_ptr();
        let mut va = [_mm256_setzero_ps(); 8];
        let mut ptrs = [base; 8];
        for j in 0..8 {
            va[j] = _mm256_set1_ps(coeffs[j]);
            ptrs[j] = base.add(offs[j]);
        }
        let mut i = 0usize;
        while i + 8 <= m {
            let mut acc = _mm256_loadu_ps(out.as_ptr().add(i));
            for j in 0..8 {
                acc = _mm256_fmadd_ps(va[j], _mm256_loadu_ps(ptrs[j].add(i)), acc);
            }
            _mm256_storeu_ps(out.as_mut_ptr().add(i), acc);
            i += 8;
        }
        while i < m {
            let mut s = *out.get_unchecked(i);
            for j in 0..8 {
                s += coeffs[j] * *ptrs[j].add(i);
            }
            *out.get_unchecked_mut(i) = s;
            i += 1;
        }
    }

    /// SAFETY: caller checked avx2+fma support and `codes.len() == out.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dequant_i8(scale: f32, codes: &[u8], out: &mut [f32]) {
        let n = out.len();
        let vs = _mm256_set1_ps(scale);
        let mut i = 0usize;
        while i + 8 <= n {
            let b = _mm_loadl_epi64(codes.as_ptr().add(i) as *const __m128i);
            let w = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(b));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(vs, w));
            i += 8;
        }
        while i < n {
            *out.get_unchecked_mut(i) = scale * (*codes.get_unchecked(i) as i8 as f32);
            i += 1;
        }
    }

    /// SAFETY: caller checked avx2+fma support; `x.len() == ga.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn scan_scored(x: &[f32], ga: &[f32], tau: f32, idx: &mut Vec<u32>) {
        let n = x.len();
        let vt = _mm256_set1_ps(tau);
        let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let mut c = 0usize;
        while c + 8 <= n {
            let xv = _mm256_loadu_ps(x.as_ptr().add(c));
            let g = _mm256_loadu_ps(ga.as_ptr().add(c));
            let s = _mm256_mul_ps(_mm256_and_ps(xv, abs_mask), g);
            let mut bits = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_GE_OQ>(s, vt)) as u32;
            while bits != 0 {
                idx.push(c as u32 + bits.trailing_zeros());
                bits &= bits - 1;
            }
            c += 8;
        }
        while c < n {
            if x.get_unchecked(c).abs() * *ga.get_unchecked(c) >= tau {
                idx.push(c as u32);
            }
            c += 1;
        }
    }

    /// SAFETY: caller checked avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn scan_threshold(x: &[f32], tau: f32, idx: &mut Vec<u32>) {
        let n = x.len();
        let vt = _mm256_set1_ps(tau);
        let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let mut c = 0usize;
        while c + 8 <= n {
            let xv = _mm256_and_ps(_mm256_loadu_ps(x.as_ptr().add(c)), abs_mask);
            let mut bits = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_GE_OQ>(xv, vt)) as u32;
            while bits != 0 {
                idx.push(c as u32 + bits.trailing_zeros());
                bits &= bits - 1;
            }
            c += 8;
        }
        while c < n {
            if x.get_unchecked(c).abs() >= tau {
                idx.push(c as u32);
            }
            c += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// NEON (aarch64). NEON is part of the aarch64 baseline, so detection always
// succeeds; the module is still behind `target_feature` for uniformity.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// SAFETY: NEON is baseline on aarch64.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(a: f32, col: &[f32], out: &mut [f32]) {
        let n = out.len();
        let va = vdupq_n_f32(a);
        let mut i = 0usize;
        while i + 4 <= n {
            let c = vld1q_f32(col.as_ptr().add(i));
            let o = vld1q_f32(out.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i), vfmaq_f32(o, va, c));
            i += 4;
        }
        while i < n {
            *out.get_unchecked_mut(i) += a * *col.get_unchecked(i);
            i += 1;
        }
    }

    /// SAFETY: NEON baseline; caller bounds-checked `offs`.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy8(coeffs: &[f32; 8], offs: &[usize; 8], data: &[f32], out: &mut [f32]) {
        let m = out.len();
        let base = data.as_ptr();
        let mut va = [vdupq_n_f32(0.0); 8];
        let mut ptrs = [base; 8];
        for j in 0..8 {
            va[j] = vdupq_n_f32(coeffs[j]);
            ptrs[j] = base.add(offs[j]);
        }
        let mut i = 0usize;
        while i + 4 <= m {
            let mut acc = vld1q_f32(out.as_ptr().add(i));
            for j in 0..8 {
                acc = vfmaq_f32(acc, va[j], vld1q_f32(ptrs[j].add(i)));
            }
            vst1q_f32(out.as_mut_ptr().add(i), acc);
            i += 4;
        }
        while i < m {
            let mut s = *out.get_unchecked(i);
            for j in 0..8 {
                s += coeffs[j] * *ptrs[j].add(i);
            }
            *out.get_unchecked_mut(i) = s;
            i += 1;
        }
    }

    /// SAFETY: NEON baseline; `codes.len() == out.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn dequant_i8(scale: f32, codes: &[u8], out: &mut [f32]) {
        let n = out.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let b = vld1_s8(codes.as_ptr().add(i) as *const i8);
            let w16 = vmovl_s8(b);
            let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w16)));
            let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w16)));
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_n_f32(lo, scale));
            vst1q_f32(out.as_mut_ptr().add(i + 4), vmulq_n_f32(hi, scale));
            i += 8;
        }
        while i < n {
            *out.get_unchecked_mut(i) = scale * (*codes.get_unchecked(i) as i8 as f32);
            i += 1;
        }
    }

    /// SAFETY: NEON baseline; `x.len() == ga.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn scan_scored(x: &[f32], ga: &[f32], tau: f32, idx: &mut Vec<u32>) {
        let n = x.len();
        let vt = vdupq_n_f32(tau);
        let mut lanes = [0u32; 4];
        let mut c = 0usize;
        while c + 4 <= n {
            let xa = vabsq_f32(vld1q_f32(x.as_ptr().add(c)));
            let s = vmulq_f32(xa, vld1q_f32(ga.as_ptr().add(c)));
            vst1q_u32(lanes.as_mut_ptr(), vcgeq_f32(s, vt));
            for (j, &hit) in lanes.iter().enumerate() {
                if hit != 0 {
                    idx.push((c + j) as u32);
                }
            }
            c += 4;
        }
        while c < n {
            if x.get_unchecked(c).abs() * *ga.get_unchecked(c) >= tau {
                idx.push(c as u32);
            }
            c += 1;
        }
    }

    /// SAFETY: NEON baseline.
    #[target_feature(enable = "neon")]
    pub unsafe fn scan_threshold(x: &[f32], tau: f32, idx: &mut Vec<u32>) {
        let n = x.len();
        let vt = vdupq_n_f32(tau);
        let mut lanes = [0u32; 4];
        let mut c = 0usize;
        while c + 4 <= n {
            let s = vabsq_f32(vld1q_f32(x.as_ptr().add(c)));
            vst1q_u32(lanes.as_mut_ptr(), vcgeq_f32(s, vt));
            for (j, &hit) in lanes.iter().enumerate() {
                if hit != 0 {
                    idx.push((c + j) as u32);
                }
            }
            c += 4;
        }
        while c < n {
            if x.get_unchecked(c).abs() >= tau {
                idx.push(c as u32);
            }
            c += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn scalar_is_always_available() {
        assert!(available_backends().contains(&Backend::Scalar));
        assert!(available_backends().contains(&best_available()));
    }

    #[test]
    fn env_off_forces_scalar() {
        assert_eq!(choose_backend(Some("off")), Backend::Scalar);
        assert_eq!(choose_backend(Some("OFF")), Backend::Scalar);
        assert_eq!(choose_backend(Some(" scalar ")), Backend::Scalar);
        assert_eq!(choose_backend(Some("0")), Backend::Scalar);
        assert_eq!(choose_backend(Some("no")), Backend::Scalar);
        assert_eq!(choose_backend(None), best_available());
        assert_eq!(choose_backend(Some("")), best_available());
        // Unknown values fail safe to the reference, never to a SIMD path.
        assert_eq!(choose_backend(Some("bogus")), Backend::Scalar);
        // A backend name this arch can't run falls back to scalar too.
        #[cfg(target_arch = "x86_64")]
        assert_eq!(choose_backend(Some("neon")), Backend::Scalar);
        #[cfg(target_arch = "aarch64")]
        assert_eq!(choose_backend(Some("avx2")), Backend::Scalar);
    }

    #[test]
    fn axpy_matches_scalar_on_odd_lengths() {
        for backend in available_backends() {
            for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 100] {
                let col = randvec(n, 1 + n as u64);
                let mut a = randvec(n, 2 + n as u64);
                let mut b = a.clone();
                scalar_axpy(0.7, &col, &mut a);
                axpy_with(backend, 0.7, &col, &mut b);
                for i in 0..n {
                    assert!((a[i] - b[i]).abs() < 1e-5, "{} n={n} i={i}", backend.name());
                }
            }
        }
    }

    #[test]
    fn axpy8_matches_scalar() {
        let m = 37;
        let data = randvec(8 * m + 5, 77);
        let coeffs = [0.3f32, -1.1, 0.0, 2.5, 0.01, -0.7, 1.0, 0.5];
        let offs = [0, m, 2 * m, 3 * m, 4 * m, 5 * m, 5, 7 * m];
        for backend in available_backends() {
            let mut a = randvec(m, 99);
            let mut b = a.clone();
            scalar_axpy8(&coeffs, &offs, &data, &mut a);
            axpy8_with(backend, &coeffs, &offs, &data, &mut b);
            for i in 0..m {
                assert!((a[i] - b[i]).abs() < 1e-4, "{} i={i}", backend.name());
            }
        }
    }

    #[test]
    fn scans_match_scalar_in_all_tau_regimes() {
        for backend in available_backends() {
            for n in [0usize, 1, 5, 8, 13, 64, 129] {
                let x = randvec(n, 3 + n as u64);
                let ga: Vec<f32> = randvec(n, 5 + n as u64)
                    .iter()
                    .map(|v| v.abs() + 0.05)
                    .collect();
                for tau in [0.0f32, 0.4, 1.5, f32::INFINITY] {
                    let mut a = Vec::new();
                    let mut b = Vec::new();
                    scan_scored_with(Backend::Scalar, &x, &ga, tau, &mut a);
                    scan_scored_with(backend, &x, &ga, tau, &mut b);
                    assert_eq!(a, b, "{} scored n={n} tau={tau}", backend.name());
                    scan_threshold_with(Backend::Scalar, &x, tau, &mut a);
                    scan_threshold_with(backend, &x, tau, &mut b);
                    assert_eq!(a, b, "{} threshold n={n} tau={tau}", backend.name());
                }
            }
        }
    }

    #[test]
    fn dequant_matches_scalar_on_odd_lengths() {
        let mut rng = Pcg64::new(21);
        for backend in available_backends() {
            for n in [0usize, 1, 3, 7, 8, 9, 15, 17, 31, 100] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u64() % 255) as u8).collect();
                let mut a = vec![0.0f32; n];
                let mut b = vec![0.0f32; n];
                scalar_dequant_i8(0.031, &codes, &mut a);
                dequant_i8_with(backend, 0.031, &codes, &mut b);
                for i in 0..n {
                    assert_eq!(
                        a[i].to_bits(),
                        b[i].to_bits(),
                        "{} n={n} i={i}",
                        backend.name()
                    );
                }
            }
        }
    }

    #[test]
    fn scan_tau_zero_keeps_everything() {
        let x = randvec(23, 9);
        let ga = vec![1.0f32; 23];
        for backend in available_backends() {
            let mut idx = Vec::new();
            scan_scored_with(backend, &x, &ga, 0.0, &mut idx);
            assert_eq!(idx, (0..23u32).collect::<Vec<_>>(), "{}", backend.name());
        }
    }
}
