//! Poison-tolerant lock acquisition.
//!
//! The supervised serving runtime catches panics (injected faults, bugs in
//! per-sequence work) with `catch_unwind`, which leaves any mutex the
//! panicking code held *poisoned*. The data behind our locks stays
//! structurally valid across every panic point — critical sections are
//! short, and the block pool / scheduler state uphold their invariants at
//! each statement — so treating poison as fatal would convert one degraded
//! request into a process-wide cascade (every later `.lock().unwrap()`
//! panicking in turn). These helpers recover the guard instead; the
//! supervisor is responsible for having already failed the implicated
//! request.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering from poison (see module docs for why this is
/// sound here).
#[inline]
pub fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Read-lock an RwLock, recovering from poison.
#[inline]
pub fn read_ok<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Write-lock an RwLock, recovering from poison.
#[inline]
pub fn write_ok<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn lock_ok_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_ok(&m), 7, "state still readable after poison");
        *lock_ok(&m) = 9;
        assert_eq!(*lock_ok(&m), 9);
    }

    #[test]
    fn rwlock_helpers_recover_from_poison() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(read_ok(&l).len(), 3);
        write_ok(&l).push(4);
        assert_eq!(read_ok(&l).len(), 4);
    }
}
