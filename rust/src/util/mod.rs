//! Offline-environment substrates: everything a normal project would pull
//! from crates.io (rand, serde_json, clap, criterion-lite, rayon-lite,
//! proptest-lite) implemented from scratch because the build is fully
//! offline and only the `xla` crate closure is vendored.

pub mod rng;
pub mod json;
pub mod cli;
pub mod stats;
pub mod threadpool;
pub mod prop;
pub mod log;
pub mod timer;
pub mod sync;

pub use rng::Pcg64;
pub use json::Json;
pub use sync::{lock_ok, read_ok, write_ok};
