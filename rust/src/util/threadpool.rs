//! Scoped data-parallel helpers (rayon replacement).
//!
//! The hot path (sparse GEMV over large output dims, calibration sweeps,
//! evolutionary-search candidate evaluation) wants simple fork-join
//! parallelism. `std::thread::scope` gives us that without any dependency;
//! this module wraps it with chunked iteration utilities.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of worker threads to use: `WISPARSE_THREADS` env override, else
/// available parallelism, else 1.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("WISPARSE_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// [`num_threads`] resolved once per process — the per-projection hot paths
/// (kernel dispatch, `lm_head`) must not re-read the environment, which
/// takes a process-global lock.
pub fn num_threads_cached() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(num_threads)
}

thread_local! {
    /// Per-thread override of the intra-op (kernel-level) thread budget.
    /// `None` = full [`num_threads_cached`] budget.
    static INTRA_BUDGET: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

/// Thread budget for intra-GEMV row parallelism on the *current* thread.
/// Defaults to [`num_threads_cached`]; batch-level workers scope it down via
/// [`with_intra_op_threads`] so nested fork-join never multiplies to
/// `threads^2` runnable threads.
pub fn intra_op_threads() -> usize {
    INTRA_BUDGET
        .with(|c| c.get())
        .unwrap_or_else(num_threads_cached)
}

/// Run `f` with the current thread's intra-op budget set to `n` (restored
/// afterwards). Used by the batched-decode workers, which already own one
/// core each.
pub fn with_intra_op_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    INTRA_BUDGET.with(|c| {
        let prev = c.replace(Some(n.max(1)));
        let out = f();
        c.set(prev);
        out
    })
}

/// Run `f(chunk_index, item_range)` over `n` items split into contiguous
/// chunks, one chunk per thread. `f` must be `Sync` (it is shared by
/// reference across the scope's threads).
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let fref = &f;
            s.spawn(move || fref(t, lo..hi));
        }
    });
}

/// Parallel map with dynamic work stealing over an index range: each worker
/// pulls the next index from a shared atomic counter. Good when per-item cost
/// varies a lot (e.g. evaluating evolutionary-search candidates).
///
/// Lock-free: every worker accumulates `(index, value)` pairs in its own
/// buffer, returned through the scoped join handle; the pairs are scattered
/// into place after the scope joins. No worker ever contends on a mutex.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, T)>> = Vec::with_capacity(threads);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let fref = &f;
            let nextref = &next;
            handles.push(s.spawn(move || {
                // Each worker owns one core: pin the kernel-level budget so
                // items that hit big projections don't fork threads^2.
                with_intra_op_threads(1, || {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = nextref.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, fref(i)));
                    }
                    local
                })
            }));
        }
        for h in handles {
            parts.push(h.join().expect("parallel_map worker panicked"));
        }
    });
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            debug_assert!(slots[i].is_none(), "index {i} produced twice");
            slots[i] = Some(v);
        }
    }
    slots
        .into_iter()
        .map(|x| x.expect("worker filled every slot"))
        .collect()
}

/// Split a mutable slice into `k` disjoint contiguous chunks and run `f` on
/// each in parallel. Used to parallelize GEMV output rows and batched
/// sequence decode without synchronization.
pub fn parallel_slices<T, F>(data: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync, // (chunk_idx, offset, chunk)
{
    parallel_slices_aligned(data, threads, 1, f)
}

/// [`parallel_slices`] with chunk boundaries aligned to multiples of
/// `align` elements (except the final chunk, which takes the remainder).
/// The kernels use `align = 8` so every output element keeps the same
/// SIMD-body/scalar-tail position as a serial pass (bit-identical results);
/// the batched GEMM uses `align = m` so chunks land on row boundaries.
/// Worker threads run with their intra-op budget pinned to 1 — each already
/// owns a core, so nested kernel fan-out must not multiply.
pub fn parallel_slices_aligned<T, F>(data: &mut [T], threads: usize, align: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync, // (chunk_idx, offset, chunk)
{
    let n = data.len();
    let align = align.max(1);
    let units = n.div_ceil(align);
    let threads = threads.max(1).min(units.max(1));
    if threads <= 1 || n == 0 {
        f(0, 0, data);
        return;
    }
    let chunk = units.div_ceil(threads) * align;
    std::thread::scope(|s| {
        let mut rest = data;
        let mut offset = 0usize;
        let mut t = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fref = &f;
            let off = offset;
            let ti = t;
            s.spawn(move || with_intra_op_threads(1, || fref(ti, off, head)));
            rest = tail;
            offset += take;
            t += 1;
        }
    });
}

/// Fork-join over contiguous row windows of a conceptual `n`-row output,
/// without handing the workers a slice: `f(row0, rows)` is called once per
/// window, boundaries aligned to `align` (final window takes the remainder).
/// The batch-fused GEMV kernels use this where [`parallel_slices_aligned`]
/// cannot express the carve — each worker writes the same row window of
/// *several* strided output rows, so no single `&mut [T]` covers its share.
/// Same chunk math as [`parallel_slices_aligned`]; workers run with their
/// intra-op budget pinned to 1.
pub fn parallel_row_windows<F>(n: usize, threads: usize, align: usize, f: F)
where
    F: Fn(usize, usize) + Sync, // (row0, rows)
{
    let align = align.max(1);
    let units = n.div_ceil(align);
    let threads = threads.max(1).min(units.max(1));
    if threads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = units.div_ceil(threads) * align;
    std::thread::scope(|s| {
        let mut row0 = 0usize;
        while row0 < n {
            let rows = chunk.min(n - row0);
            let fref = &f;
            s.spawn(move || with_intra_op_threads(1, || fref(row0, rows)));
            row0 += rows;
        }
    });
}

/// Raw `*mut f32` that crosses [`parallel_row_windows`] worker boundaries.
/// Safe to send because the workers write disjoint (row-window × stride)
/// regions; each reconstructs only its own windows from the base pointer.
#[derive(Clone, Copy)]
pub struct SendPtr(pub *mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn map_identity() {
        let out = parallel_map(100, 4, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_thread() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out.len(), 10);
        assert_eq!(out[9], 10);
    }

    #[test]
    fn chunks_cover_everything() {
        let seen = Mutex::new(vec![false; 1000]);
        parallel_chunks(1000, 7, |_, range| {
            let mut s = seen.lock().unwrap();
            for i in range {
                assert!(!s[i], "index {i} visited twice");
                s[i] = true;
            }
        });
        assert!(seen.into_inner().unwrap().into_iter().all(|b| b));
    }

    #[test]
    fn slices_disjoint_and_complete() {
        let mut data = vec![0usize; 97];
        parallel_slices(&mut data, 4, |_, offset, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = offset + i;
            }
        });
        assert_eq!(data, (0..97).collect::<Vec<_>>());
    }

    #[test]
    fn zero_items() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
        parallel_chunks(0, 4, |_, r| assert!(r.is_empty()));
    }

    #[test]
    fn aligned_slices_land_on_alignment_boundaries() {
        let mut data = vec![0usize; 103];
        let chunks_seen = Mutex::new(Vec::new());
        parallel_slices_aligned(&mut data, 4, 8, |_, off, chunk| {
            chunks_seen.lock().unwrap().push((off, chunk.len()));
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = off + i;
            }
        });
        assert_eq!(data, (0..103).collect::<Vec<_>>());
        for &(off, len) in chunks_seen.lock().unwrap().iter() {
            assert_eq!(off % 8, 0, "chunk offset {off} not aligned");
            if off + len < 103 {
                assert_eq!(len % 8, 0, "interior chunk length {len} not aligned");
            }
        }
    }

    #[test]
    fn row_windows_cover_everything_aligned() {
        let seen = Mutex::new(vec![false; 103]);
        parallel_row_windows(103, 4, 8, |row0, rows| {
            assert_eq!(row0 % 8, 0, "window offset {row0} not aligned");
            let mut s = seen.lock().unwrap();
            for i in row0..row0 + rows {
                assert!(!s[i], "row {i} visited twice");
                s[i] = true;
            }
        });
        assert!(seen.into_inner().unwrap().into_iter().all(|b| b));
        parallel_row_windows(0, 4, 8, |row0, rows| {
            assert_eq!((row0, rows), (0, 0));
        });
    }

    #[test]
    fn intra_budget_scoped_and_restored() {
        let base = intra_op_threads();
        with_intra_op_threads(1, || {
            assert_eq!(intra_op_threads(), 1);
            with_intra_op_threads(3, || assert_eq!(intra_op_threads(), 3));
            assert_eq!(intra_op_threads(), 1);
        });
        assert_eq!(intra_op_threads(), base);
        // Fan-out workers run with the budget pinned to 1.
        let seen = parallel_map(4, 4, |_| intra_op_threads());
        assert!(seen.iter().all(|&n| n == 1), "worker budgets: {seen:?}");
    }
}
