//! Scoped data-parallel helpers (rayon replacement).
//!
//! The hot path (sparse GEMV over large output dims, calibration sweeps,
//! evolutionary-search candidate evaluation) wants simple fork-join
//! parallelism. `std::thread::scope` gives us that without any dependency;
//! this module wraps it with chunked iteration utilities.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: `WISPARSE_THREADS` env override, else
/// available parallelism, else 1.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("WISPARSE_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(chunk_index, item_range)` over `n` items split into contiguous
/// chunks, one chunk per thread. `f` must be `Sync` (it is shared by
/// reference across the scope's threads).
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let fref = &f;
            s.spawn(move || fref(t, lo..hi));
        }
    });
}

/// Parallel map with dynamic work stealing over an index range: each worker
/// pulls the next index from a shared atomic counter. Good when per-item cost
/// varies a lot (e.g. evaluating evolutionary-search candidates).
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..threads {
            let fref = &f;
            let nextref = &next;
            let resref = &results;
            s.spawn(move || loop {
                let i = nextref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = fref(i);
                resref.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|x| x.expect("worker filled every slot"))
        .collect()
}

/// Split a mutable slice into `k` disjoint contiguous chunks and run `f` on
/// each in parallel. Used to parallelize GEMV output rows without
/// synchronization.
pub fn parallel_slices<T, F>(data: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync, // (chunk_idx, offset, chunk)
{
    let n = data.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0, 0, data);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut offset = 0usize;
        let mut t = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fref = &f;
            let off = offset;
            let ti = t;
            s.spawn(move || fref(ti, off, head));
            rest = tail;
            offset += take;
            t += 1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_identity() {
        let out = parallel_map(100, 4, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_thread() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out.len(), 10);
        assert_eq!(out[9], 10);
    }

    #[test]
    fn chunks_cover_everything() {
        let seen = Mutex::new(vec![false; 1000]);
        parallel_chunks(1000, 7, |_, range| {
            let mut s = seen.lock().unwrap();
            for i in range {
                assert!(!s[i], "index {i} visited twice");
                s[i] = true;
            }
        });
        assert!(seen.into_inner().unwrap().into_iter().all(|b| b));
    }

    #[test]
    fn slices_disjoint_and_complete() {
        let mut data = vec![0usize; 97];
        parallel_slices(&mut data, 4, |_, offset, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = offset + i;
            }
        });
        assert_eq!(data, (0..97).collect::<Vec<_>>());
    }

    #[test]
    fn zero_items() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
        parallel_chunks(0, 4, |_, r| assert!(r.is_empty()));
    }
}
