//! Leveled stderr logger with wall-clock timestamps relative to process
//! start. Controlled by `WISPARSE_LOG` (error|warn|info|debug|trace).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static START: OnceLock<Instant> = OnceLock::new();
static INIT: OnceLock<()> = OnceLock::new();

fn init() {
    INIT.get_or_init(|| {
        START.get_or_init(Instant::now);
        if let Ok(s) = std::env::var("WISPARSE_LOG") {
            let lv = match s.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "info" => Level::Info,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            };
            LEVEL.store(lv as u8, Ordering::Relaxed);
        }
    });
}

pub fn set_level(lv: Level) {
    init();
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

pub fn enabled(lv: Level) -> bool {
    init();
    (lv as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lv: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(lv) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    eprintln!(
        "[{:>9.3}s {} {}] {}",
        t.as_secs_f64(),
        lv.tag(),
        module,
        msg
    );
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
