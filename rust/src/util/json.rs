//! Minimal JSON value type, recursive-descent parser and serializer.
//!
//! Replaces `serde_json` in the offline environment. Covers the full JSON
//! grammar (RFC 8259) including escapes and scientific-notation numbers; the
//! repository uses it for config files, sparsity plans, eval task sets and
//! the AOT parameter manifest written by the Python side.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for debuggability.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| {
            if x.fract() == 0.0 {
                Some(x as i64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required typed accessors used by config loading.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field `{key}`"))
    }

    /// Array of f64, common for sparsity/alpha vectors.
    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect::<Vec<_>>())
            .filter(|v| v.len() == self.as_arr().map(|a| a.len()).unwrap_or(0))
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    // JSON has no Inf/NaN; emit null like serde_json does.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 already advanced pos
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "3.25e2", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let back = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Json::Null);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\nb\t\"q\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\"A"));
        let round = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.5, -3.0])),
            ("name", Json::Str("wisparse".into())),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn big_array_roundtrip() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64 * 0.123).collect();
        let v = Json::arr_f64(&xs);
        let back = Json::parse(&v.to_string_compact()).unwrap();
        let ys = back.f64_vec().unwrap();
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 5, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 5);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_f64("f").unwrap(), 1.5);
        assert!(v.req_usize("missing").is_err());
        assert!(v.req_usize("f").is_err());
    }
}
