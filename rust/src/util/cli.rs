//! Tiny declarative CLI argument parser (clap replacement).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! per-subcommand help text. The binary dispatches subcommands itself; this
//! module only parses one subcommand's argument list.

use std::collections::BTreeMap;

/// Declared option.
struct Opt {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative argument parser for one subcommand.
pub struct Args {
    cmd: &'static str,
    about: &'static str,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(cmd: &'static str, about: &'static str) -> Self {
        Self {
            cmd,
            about,
            opts: Vec::new(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Declare a `--name <value>` option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            takes_value: true,
            default: Some(default.to_string()),
        });
        self
    }

    /// Declare a required `--name <value>` option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            takes_value: true,
            default: None,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    fn usage(&self) -> String {
        let mut s = format!("wisparse {} — {}\n\noptions:\n", self.cmd, self.about);
        for o in &self.opts {
            let head = if o.takes_value {
                format!("  --{} <v>", o.name)
            } else {
                format!("  --{}", o.name)
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:28} {}{def}\n", o.help));
        }
        s
    }

    /// Parse an argument list. Returns Err with usage text on bad input or
    /// `--help`.
    pub fn parse(mut self, argv: &[String]) -> anyhow::Result<Args> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                anyhow::bail!("{}", self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n{}", self.usage()))?;
                if opt.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?
                        }
                    };
                    self.values.insert(key, val);
                } else {
                    if inline_val.is_some() {
                        anyhow::bail!("--{key} does not take a value");
                    }
                    self.flags.insert(key, true);
                }
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        // Apply defaults, check required.
        for o in &self.opts {
            if o.takes_value && !self.values.contains_key(o.name) {
                match &o.default {
                    Some(d) => {
                        self.values.insert(o.name.to_string(), d.clone());
                    }
                    None => anyhow::bail!("missing required --{}\n{}", o.name, self.usage()),
                }
            }
        }
        Ok(self)
    }

    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} was not declared"))
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        self.get(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be an integer, got `{}`", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        self.get(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be a number, got `{}`", self.get(name)))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Comma-separated list of f64 (e.g. `--sparsities 0.3,0.4,0.5`).
    pub fn get_f64_list(&self, name: &str) -> anyhow::Result<Vec<f64>> {
        self.get(name)
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("--{name}: `{s}` is not a number"))
            })
            .collect()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::new("t", "test")
            .opt("model", "llama-micro", "model name")
            .opt("steps", "10", "steps")
            .parse(&v(&["--steps", "20"]))
            .unwrap();
        assert_eq!(a.get("model"), "llama-micro");
        assert_eq!(a.get_usize("steps").unwrap(), 20);
    }

    #[test]
    fn eq_syntax_and_flags() {
        let a = Args::new("t", "test")
            .opt("x", "1", "")
            .flag("verbose", "")
            .parse(&v(&["--x=5", "--verbose"]))
            .unwrap();
        assert_eq!(a.get("x"), "5");
        assert!(a.get_flag("verbose"));
    }

    #[test]
    fn required_missing() {
        let r = Args::new("t", "test").req("out", "").parse(&v(&[]));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let r = Args::new("t", "test").parse(&v(&["--bogus"]));
        assert!(r.is_err());
    }

    #[test]
    fn f64_list() {
        let a = Args::new("t", "test")
            .opt("sparsities", "0.3,0.4,0.5", "")
            .parse(&v(&[]))
            .unwrap();
        assert_eq!(a.get_f64_list("sparsities").unwrap(), vec![0.3, 0.4, 0.5]);
    }

    #[test]
    fn positional_collected() {
        let a = Args::new("t", "test").parse(&v(&["one", "two"])).unwrap();
        assert_eq!(a.positional(), &["one".to_string(), "two".to_string()]);
    }
}
