//! Mini property-based testing harness (proptest replacement).
//!
//! Provides seeded random case generation with bounded shrinking: when a
//! case fails, the harness retries with "smaller" inputs produced by the
//! generator's `shrink` to report a minimal-ish counterexample. Used by the
//! invariant tests on routing, batching, masks and allocation.

use crate::util::rng::Pcg64;

/// A generator of random values with an optional shrinker.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;
    /// Candidate smaller values; default none.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.below(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
        }
        out.dedup();
        out
    }
}

/// f64 in [lo, hi).
pub struct F64In(pub f64, pub f64);

impl Gen for F64In {
    type Value = f64;
    fn generate(&self, rng: &mut Pcg64) -> f64 {
        rng.range_f64(self.0, self.1)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mid = self.0 + (*v - self.0) / 2.0;
        if (mid - *v).abs() > 1e-9 {
            vec![self.0, mid]
        } else {
            vec![]
        }
    }
}

/// Vec<f32> of length in [min_len, max_len], values in [lo, hi).
pub struct VecF32 {
    pub min_len: usize,
    pub max_len: usize,
    pub lo: f32,
    pub hi: f32,
}

impl Gen for VecF32 {
    type Value = Vec<f32>;
    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        let n = self.min_len + rng.below(self.max_len - self.min_len + 1);
        (0..n)
            .map(|_| self.lo + (self.hi - self.lo) * rng.next_f32())
            .collect()
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // Halve the tail.
            let keep = (v.len() / 2).max(self.min_len);
            out.push(v[..keep].to_vec());
        }
        // Zero everything (often the minimal interesting case).
        if v.iter().any(|&x| x != 0.0) && self.lo <= 0.0 {
            out.push(vec![0.0; v.len()]);
        }
        out
    }
}

/// Result of a property check.
pub struct CheckConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for CheckConfig {
    fn default() -> Self {
        Self {
            cases: 100,
            seed: 0xC0FFEE,
            max_shrink_steps: 200,
        }
    }
}

/// Check `prop` over `cfg.cases` generated values. Panics with the minimal
/// found counterexample on failure (so it composes with `#[test]`).
pub fn check<G, P>(cfg: &CheckConfig, gen: &G, prop: P)
where
    G: Gen,
    P: Fn(&G::Value) -> Result<(), String>,
{
    let mut rng = Pcg64::new(cfg.seed);
    for case in 0..cfg.cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // Shrink.
            let mut best = v.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: loop {
                for cand in gen.shrink(&best) {
                    steps += 1;
                    if steps > cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {}): {best_msg}\ncounterexample: {best:?}",
                cfg.seed
            );
        }
    }
}

/// Check a property over pairs from two generators.
pub fn check2<G1, G2, P>(cfg: &CheckConfig, g1: &G1, g2: &G2, prop: P)
where
    G1: Gen,
    G2: Gen,
    P: Fn(&G1::Value, &G2::Value) -> Result<(), String>,
{
    let mut rng = Pcg64::new(cfg.seed);
    for case in 0..cfg.cases {
        let a = g1.generate(&mut rng);
        let b = g2.generate(&mut rng);
        if let Err(msg) = prop(&a, &b) {
            panic!("property failed (case {case}): {msg}\ninputs: {a:?}, {b:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(&CheckConfig::default(), &UsizeIn(0, 100), |&n| {
            if n <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(&CheckConfig::default(), &UsizeIn(0, 100), |&n| {
            if n < 50 {
                Ok(())
            } else {
                Err(format!("{n} >= 50"))
            }
        });
    }

    #[test]
    fn vec_gen_respects_bounds() {
        check(
            &CheckConfig::default(),
            &VecF32 {
                min_len: 2,
                max_len: 64,
                lo: -1.0,
                hi: 1.0,
            },
            |v| {
                if v.len() < 2 || v.len() > 64 {
                    return Err(format!("len {}", v.len()));
                }
                if v.iter().any(|&x| !(-1.0..1.0).contains(&x)) {
                    return Err("value out of range".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pairs() {
        check2(
            &CheckConfig::default(),
            &UsizeIn(1, 10),
            &UsizeIn(1, 10),
            |&a, &b| {
                if a * b >= a {
                    Ok(())
                } else {
                    Err("mult".into())
                }
            },
        );
    }
}
