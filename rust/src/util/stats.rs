//! Small statistics helpers shared by calibration, eval and the bench
//! harness: means, quantiles, and streaming summaries.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// q-quantile (0 <= q <= 1) with linear interpolation, like numpy's default.
/// Sorts a copy; fine for calibration-sized data.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// q-quantile of an already-sorted slice.
pub fn quantile_sorted(v: &[f64], q: f64) -> f64 {
    let n = v.len();
    if n == 1 {
        return v[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// f32 variant used on activation scores (hot during calibration).
pub fn quantile_f32(xs: &[f32], q: f64) -> f32 {
    assert!(!xs.is_empty());
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n == 1 {
        return v[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = (pos - lo as f64) as f32;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Select the k-th smallest element (0-based) in O(n) average via quickselect.
/// Used for exact top-k thresholds on large score vectors without a full sort.
pub fn select_kth_f32(xs: &mut [f32], k: usize) -> f32 {
    assert!(k < xs.len());
    let mut lo = 0usize;
    let mut hi = xs.len() - 1;
    // Deterministic pivot walk (median-of-three) to avoid adversarial cases.
    loop {
        if lo == hi {
            return xs[lo];
        }
        let mid = lo + (hi - lo) / 2;
        // median-of-three pivot
        let (a, b, c) = (xs[lo], xs[mid], xs[hi]);
        let pivot = if (a <= b) == (b <= c) {
            b
        } else if (b <= a) == (a <= c) {
            a
        } else {
            c
        };
        // 3-way partition
        let (mut i, mut j, mut p) = (lo, hi, lo);
        while p <= j {
            if xs[p] < pivot {
                xs.swap(p, i);
                i += 1;
                p += 1;
            } else if xs[p] > pivot {
                xs.swap(p, j);
                if j == 0 {
                    break;
                }
                j -= 1;
            } else {
                p += 1;
            }
        }
        if k < i {
            hi = i - 1;
        } else if k > j {
            lo = j + 1;
        } else {
            return pivot;
        }
    }
}

/// Streaming summary used by the serving metrics: count / mean / min / max
/// with reservoir-free exact percentiles over a bounded window.
#[derive(Clone, Debug)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    window: Vec<f64>,
    cap: usize,
}

impl Summary {
    pub fn new(window_cap: usize) -> Self {
        Self {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            window: Vec::new(),
            cap: window_cap.max(1),
        }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.window.len() == self.cap {
            // Overwrite ring-style.
            let i = (self.count as usize - 1) % self.cap;
            self.window[i] = x;
        } else {
            self.window.push(x);
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Percentile over the retained window (recent values).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        quantile(&self.window, q)
    }

    /// Fold another summary into this one (the router's aggregate view over
    /// per-replica metrics). Counts and extrema merge exactly; the
    /// percentile window absorbs the other's retained samples up to its own
    /// capacity, so aggregate percentiles are computed over a bounded blend
    /// of every replica's recent values.
    pub fn merge_from(&mut self, o: &Summary) {
        self.count += o.count;
        self.sum += o.sum;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
        for &v in &o.window {
            if self.window.len() >= self.cap {
                break;
            }
            self.window.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn mean_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn quantile_matches_numpy_convention() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-12);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn quantile_unsorted_input() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn select_kth_matches_sort() {
        let mut r = Pcg64::new(17);
        for n in [1usize, 2, 3, 10, 101, 1000] {
            let xs: Vec<f32> = (0..n).map(|_| r.next_f32() * 100.0).collect();
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for k in [0, n / 3, n / 2, n - 1] {
                let mut work = xs.clone();
                assert_eq!(select_kth_f32(&mut work, k), sorted[k], "n={n} k={k}");
            }
        }
    }

    #[test]
    fn select_kth_with_duplicates() {
        let mut xs = vec![5.0f32; 100];
        assert_eq!(select_kth_f32(&mut xs, 50), 5.0);
        let mut xs: Vec<f32> = (0..100).map(|i| (i % 3) as f32).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(select_kth_f32(&mut xs, 70), sorted[70]);
    }

    #[test]
    fn summary_percentiles_with_few_samples() {
        // Fewer samples than the window capacity: percentiles interpolate
        // over exactly the recorded values, never uninitialized slots.
        let mut s = Summary::new(1024);
        for x in [3.0, 1.0, 2.0] {
            s.add(x);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(1.0), 3.0);
        assert!((s.percentile(0.5) - 2.0).abs() < 1e-12);
        let p99 = s.percentile(0.99);
        assert!((2.0..=3.0).contains(&p99) && p99 > 2.9, "p99 {p99}");
        // Empty summary is defined (0.0), not a panic.
        assert_eq!(Summary::new(8).percentile(0.99), 0.0);
    }

    #[test]
    fn summary_window() {
        let mut s = Summary::new(4);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0, 6.0] {
            s.add(x);
        }
        assert_eq!(s.count, 6);
        assert_eq!(s.max, 6.0);
        assert_eq!(s.min, 1.0);
        assert!((s.mean() - 3.5).abs() < 1e-12);
        // Window holds the last 4 values {5, 6, 3, 4}.
        assert!(s.percentile(1.0) >= 5.0);
    }
}
