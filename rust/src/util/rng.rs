//! PCG64 (XSL-RR variant) pseudo-random number generator.
//!
//! The repository must be fully reproducible, and the offline environment has
//! no `rand` crate, so we carry our own small, well-tested PRNG. PCG64 is the
//! same generator `rand::rngs::Pcg64` wraps; see O'Neill, "PCG: A Family of
//! Simple Fast Space-Efficient Statistically Good Algorithms for Random
//! Number Generation" (2014).

/// PCG64 XSL-RR generator with a fixed odd increment.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id (useful to give each
    /// thread / each search an independent sequence from one master seed).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        // XSL-RR output function.
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) via Lemire's unbiased method.
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0) is meaningless");
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::new(3);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.1).abs() < 0.01, "bucket p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(5);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::with_stream(42, 0);
        let mut b = Pcg64::with_stream(42, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
