//! Benchmark timing substrate (criterion replacement).
//!
//! `Bench` runs a closure repeatedly with warmup, measures per-iteration
//! wall time, and reports mean / median / p10 / p90 plus derived throughput.
//! Bench targets in `benches/` use `harness = false` and drive this.

use std::time::{Duration, Instant};

/// One measured distribution of iteration times.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// items/sec given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_secs()
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>12}  median {:>12}  p90 {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p90_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner configuration.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            min_iters: 5,
            max_iters: 100_000,
        }
    }

    /// Run `f` repeatedly and collect per-iteration timings. A `black_box`
    /// on the closure's output is the caller's responsibility (return a
    /// value and `std::hint::black_box` it inside `f`).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut times_ns: Vec<f64> = Vec::new();
        let m0 = Instant::now();
        while (m0.elapsed() < self.measure || times_ns.len() < self.min_iters)
            && times_ns.len() < self.max_iters
        {
            let t0 = Instant::now();
            f();
            times_ns.push(t0.elapsed().as_nanos() as f64);
        }
        times_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times_ns.len();
        let mean = times_ns.iter().sum::<f64>() / n as f64;
        BenchResult {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: crate::util::stats::quantile_sorted(&times_ns, 0.5),
            p10_ns: crate::util::stats::quantile_sorted(&times_ns, 0.1),
            p90_ns: crate::util::stats::quantile_sorted(&times_ns, 0.9),
            min_ns: times_ns[0],
        }
    }
}

/// Simple scope timer for coarse phase reporting.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 10_000,
        };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.p90_ns >= r.median_ns);
        assert!(r.median_ns >= r.min_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
