//! `wisparse` — the leader binary: data generation, calibration, serving,
//! and one subcommand per paper table/figure.

mod cmd;

const USAGE: &str = "\
wisparse — Weight-aware Mixed-Granularity Training-free Activation Sparsity

USAGE: wisparse <command> [options]   (--help per command)

setup
  gen-data      generate the synthetic corpus + calibration sets
  calibrate     run a calibration pipeline, write a sparsity plan
  quantize      group-quantize a checkpoint (int8/int4) and recalibrate
  validate      cross-validate native engine vs PJRT-compiled HLO

serving
  serve         start the HTTP serving coordinator
  bench-decode  end-to-end decode throughput for one configuration
  profile       per-block density/bandwidth profile vs STREAM roofline

experiments (regenerate the paper's tables and figures)
  table1        accuracy: methods x sparsities x models (Table 1)
  table2        component ablation at 50% (Table 2)
  fig2          activation vs weight-norm distributions (Fig 2)
  fig3          block-wise sparsity sensitivity (Fig 3)
  fig4          FLOPs + tokens/s vs sparsity (Fig 4)
  fig5          discovered per-block/module sparsity (Fig 5)
  fig6          calibrated alpha values per layer (Fig 6)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match cmd {
        "gen-data" => cmd::gen_data::run(&rest),
        "calibrate" => cmd::calibrate::run(&rest),
        "quantize" => cmd::quantize::run(&rest),
        "validate" => cmd::validate::run(&rest),
        "serve" => cmd::serve::run(&rest),
        "bench-decode" => cmd::bench_decode::run(&rest),
        "profile" => cmd::profile::run(&rest),
        "table1" => cmd::table1::run(&rest),
        "table2" => cmd::table2::run(&rest),
        "fig2" => cmd::figs::fig2(&rest),
        "fig3" => cmd::figs::fig3(&rest),
        "fig4" => cmd::figs::fig4(&rest),
        "fig5" => cmd::figs::fig5(&rest),
        "fig6" => cmd::figs::fig6(&rest),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
