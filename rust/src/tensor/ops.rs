//! Numeric primitives for the native transformer engine.
//!
//! These are the *reference* (dense) implementations; the optimized sparse
//! paths live in `sparse_kernel/`. Conventions match the JAX model in
//! `python/compile/model.py` exactly so the PJRT cross-validation can assert
//! near-bit agreement: RMSNorm without bias, rotary embeddings in half-split
//! layout, causal attention with 1/sqrt(d) scaling, SwiGLU MLP.

use crate::tensor::Tensor;

/// y = x @ W^T where x: [s, n], w: [m, n] -> y: [s, m].
///
/// This matches the projection convention of Eq. 1 in the paper (weights
/// stored output-major, as PyTorch/JAX linear layers do).
pub fn matmul_xwt(x: &Tensor, w: &Tensor) -> Tensor {
    let (s, n) = x.dims2();
    let (m, n2) = w.dims2();
    assert_eq!(n, n2, "x cols {n} vs w cols {n2}");
    let mut out = Tensor::zeros(&[s, m]);
    for i in 0..s {
        let xr = x.row(i);
        let or = out.row_mut(i);
        for (j, o) in or.iter_mut().enumerate() {
            let wr = w.row(j);
            let mut acc = 0.0f32;
            for k in 0..n {
                acc += xr[k] * wr[k];
            }
            *o = acc;
        }
    }
    out
}

/// Plain a @ b: a[s, k] x b[k, m] -> [s, m].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (s, k) = a.dims2();
    let (k2, m) = b.dims2();
    assert_eq!(k, k2);
    let mut out = Tensor::zeros(&[s, m]);
    for i in 0..s {
        let ar = a.row(i);
        let or = out.row_mut(i);
        for (kk, &av) in ar.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let br = &b.data[kk * m..(kk + 1) * m];
            for j in 0..m {
                or[j] += av * br[j];
            }
        }
    }
    out
}

/// In-place numerically-stable softmax over the last dim of a 2-D tensor.
pub fn softmax_rows(x: &mut Tensor) {
    let (r, _) = x.dims2();
    for i in 0..r {
        softmax_inplace(x.row_mut(i));
    }
}

/// Numerically-stable softmax on a slice.
pub fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// log-softmax into a new vector (used by eval for logprobs / KL).
pub fn log_softmax(row: &[f32]) -> Vec<f32> {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let logsum = row.iter().map(|&v| ((v - max) as f64).exp()).sum::<f64>().ln() as f32 + max;
    row.iter().map(|&v| v - logsum).collect()
}

/// RMSNorm: x * w / rms(x), rms over the last dim. eps matches JAX side.
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    assert_eq!(x.len(), w.len());
    assert_eq!(x.len(), out.len());
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / ((ms as f32 + eps).sqrt());
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// Rotary position embedding, half-split layout (as in Llama/JAX):
/// for head dim d, pairs are (i, i + d/2). `pos` is the absolute position.
/// theta-base matches the python side (10000.0).
pub fn rope_inplace(q: &mut [f32], pos: usize, rope_base: f32) {
    let d = q.len();
    assert!(d % 2 == 0, "head dim must be even");
    let half = d / 2;
    for i in 0..half {
        let freq = 1.0 / rope_base.powf(2.0 * i as f32 / d as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let a = q[i];
        let b = q[i + half];
        q[i] = a * cos - b * sin;
        q[i + half] = a * sin + b * cos;
    }
}

/// SiLU activation: x * sigmoid(x).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// argmax of a slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Indices of the k largest values (descending by value).
pub fn topk_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    let k = k.min(xs.len());
    idx.select_nth_unstable_by(k.saturating_sub(1).min(xs.len() - 1), |&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap()
    });
    idx.truncate(k);
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn matmul_xwt_small() {
        // x = [[1, 2]], W = [[3, 4], [5, 6]] (2 outputs, 2 inputs)
        // y = [1*3+2*4, 1*5+2*6] = [11, 17]
        let x = Tensor::from_vec(&[1, 2], vec![1., 2.]);
        let w = Tensor::from_vec(&[2, 2], vec![3., 4., 5., 6.]);
        let y = matmul_xwt(&x, &w);
        assert_eq!(y.data, vec![11., 17.]);
    }

    #[test]
    fn matmul_agrees_with_xwt() {
        let mut rng = Pcg64::new(2);
        let x = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let w = Tensor::randn(&[4, 5], 1.0, &mut rng);
        let a = matmul_xwt(&x, &w);
        let b = matmul(&x, &w.transpose2());
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., -1., 0., 100.]);
        softmax_rows(&mut t);
        for i in 0..2 {
            let s: f32 = t.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // Large logit dominates without NaN.
        assert!(t.at2(1, 2) > 0.999);
    }

    #[test]
    fn log_softmax_consistent() {
        let row = vec![0.5f32, -1.0, 2.0, 0.0];
        let ls = log_softmax(&row);
        let total: f32 = ls.iter().map(|&v| v.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        let mut sm = row.clone();
        softmax_inplace(&mut sm);
        for (a, b) in ls.iter().zip(&sm) {
            assert!((a.exp() - b).abs() < 1e-5);
        }
    }

    #[test]
    fn rmsnorm_unit_property() {
        // rmsnorm of a constant vector with unit weights -> ±1 values.
        let x = vec![3.0f32; 8];
        let w = vec![1.0f32; 8];
        let mut out = vec![0.0; 8];
        rmsnorm(&x, &w, 1e-5, &mut out);
        for &v in &out {
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Pcg64::new(3);
        let mut q: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        let norm0: f32 = q.iter().map(|v| v * v).sum();
        rope_inplace(&mut q, 7, 10000.0);
        let norm1: f32 = q.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() < 1e-3);
    }

    #[test]
    fn rope_pos_zero_is_identity() {
        let mut q: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let orig = q.clone();
        rope_inplace(&mut q, 0, 10000.0);
        for (a, b) in q.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn silu_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!(silu(10.0) > 9.9);
        assert!(silu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn topk() {
        let xs = vec![0.1f32, 5.0, -1.0, 3.0, 4.0];
        assert_eq!(topk_indices(&xs, 3), vec![1, 4, 3]);
        assert_eq!(argmax(&xs), 1);
        assert_eq!(topk_indices(&xs, 10).len(), 5);
    }
}
