//! Host tensor substrate: dense f32 tensors plus the numeric primitives the
//! transformer engine needs (matmul, softmax, rmsnorm, rope) and a small
//! linear-algebra toolbox (power-iteration SVD for the R-Sparse baseline).

pub mod dense;
pub mod ops;
pub mod linalg;

pub use dense::Tensor;
