//! Small linear-algebra toolbox: truncated SVD via subspace (block power)
//! iteration. Needed by the R-Sparse baseline, which routes low-magnitude
//! activations through a precomputed rank-r approximation of each weight
//! matrix (Zhang et al., 2025).

use crate::tensor::ops::matmul;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Truncated SVD `W ≈ U diag(s) V^T` with `U: [m, r]`, `V: [n, r]`.
#[derive(Clone, Debug)]
pub struct TruncatedSvd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub v: Tensor,
}

impl TruncatedSvd {
    /// Reconstruct the rank-r approximation (test/diagnostic use).
    pub fn reconstruct(&self) -> Tensor {
        let (m, r) = self.u.dims2();
        let (n, _) = self.v.dims2();
        let mut us = self.u.clone();
        for i in 0..m {
            for j in 0..r {
                us.data[i * r + j] *= self.s[j];
            }
        }
        matmul(&us, &self.v.transpose2()).reshape(&[m, n])
    }

    /// Low-rank matvec: y = W_r x = U diag(s) V^T x. O((m+n) r).
    pub fn matvec(&self, x: &[f32], out: &mut [f32]) {
        let (m, r) = self.u.dims2();
        let (n, _) = self.v.dims2();
        assert_eq!(x.len(), n);
        assert_eq!(out.len(), m);
        // t = diag(s) V^T x
        let mut t = vec![0.0f32; r];
        for j in 0..r {
            let mut acc = 0.0f32;
            for i in 0..n {
                acc += self.v.data[i * r + j] * x[i];
            }
            t[j] = acc * self.s[j];
        }
        // out = U t
        for i in 0..m {
            let ur = &self.u.data[i * r..(i + 1) * r];
            let mut acc = 0.0f32;
            for j in 0..r {
                acc += ur[j] * t[j];
            }
            out[i] = acc;
        }
    }

    /// Low-rank matvec restricted to a channel subset: y = U diag(s)
    /// (V[S,:])^T x[S]. Used by R-Sparse to route *pruned* channels through
    /// the low-rank path.
    pub fn matvec_subset(&self, x: &[f32], channels: &[usize], out: &mut [f32]) {
        let (m, r) = self.u.dims2();
        let mut t = vec![0.0f32; r];
        for &c in channels {
            let xv = x[c];
            if xv == 0.0 {
                continue;
            }
            let vr = &self.v.data[c * r..(c + 1) * r];
            for j in 0..r {
                t[j] += vr[j] * xv;
            }
        }
        for j in 0..r {
            t[j] *= self.s[j];
        }
        for i in 0..m {
            let ur = &self.u.data[i * r..(i + 1) * r];
            let mut acc = 0.0f32;
            for j in 0..r {
                acc += ur[j] * t[j];
            }
            out[i] = acc;
        }
    }
}

/// Orthonormalize the columns of a [m, r] matrix in place (modified
/// Gram-Schmidt). Returns false if a column collapsed to ~zero.
fn orthonormalize_cols(q: &mut Tensor) -> bool {
    let (m, r) = q.dims2();
    for j in 0..r {
        // Subtract projections onto previous columns.
        for k in 0..j {
            let mut dot = 0.0f64;
            for i in 0..m {
                dot += q.data[i * r + j] as f64 * q.data[i * r + k] as f64;
            }
            for i in 0..m {
                q.data[i * r + j] -= (dot as f32) * q.data[i * r + k];
            }
        }
        let norm = (0..m)
            .map(|i| (q.data[i * r + j] as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        if norm < 1e-12 {
            return false;
        }
        let inv = (1.0 / norm) as f32;
        for i in 0..m {
            q.data[i * r + j] *= inv;
        }
    }
    true
}

/// Truncated SVD of `w` ([m, n]) to rank `rank` via subspace iteration with
/// `iters` power steps (default 12 is plenty for the decaying spectra of
/// trained weight matrices).
pub fn truncated_svd(w: &Tensor, rank: usize, iters: usize, seed: u64) -> TruncatedSvd {
    let (m, n) = w.dims2();
    let r = rank.min(m).min(n).max(1);
    let mut rng = Pcg64::new(seed);
    // Start from a random [n, r] block.
    let mut v = Tensor::randn(&[n, r], 1.0, &mut rng);
    orthonormalize_cols(&mut v);
    let wt = w.transpose2();
    #[allow(unused_assignments)]
    let mut u = Tensor::zeros(&[m, r]);
    for _ in 0..iters.max(1) {
        // u = W v ; orthonormalize
        u = matmul(w, &v);
        if !orthonormalize_cols(&mut u) {
            // Degenerate: re-randomize the collapsed subspace.
            u = Tensor::randn(&[m, r], 1.0, &mut rng);
            orthonormalize_cols(&mut u);
        }
        // v = W^T u ; orthonormalize
        v = matmul(&wt, &u);
        if !orthonormalize_cols(&mut v) {
            v = Tensor::randn(&[n, r], 1.0, &mut rng);
            orthonormalize_cols(&mut v);
        }
    }
    // Final pass: u_raw = W v; s_j = ||u_raw[:, j]||; u = u_raw / s.
    let u_raw = matmul(w, &v);
    let mut s = vec![0.0f32; r];
    let mut u_final = Tensor::zeros(&[m, r]);
    for j in 0..r {
        let norm = (0..m)
            .map(|i| (u_raw.data[i * r + j] as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        s[j] = norm as f32;
        let inv = if norm > 1e-12 { (1.0 / norm) as f32 } else { 0.0 };
        for i in 0..m {
            u_final.data[i * r + j] = u_raw.data[i * r + j] * inv;
        }
    }
    // Sort singular triplets by decreasing s.
    let mut order: Vec<usize> = (0..r).collect();
    order.sort_by(|&a, &b| s[b].partial_cmp(&s[a]).unwrap());
    let mut u_sorted = Tensor::zeros(&[m, r]);
    let mut v_sorted = Tensor::zeros(&[n, r]);
    let mut s_sorted = vec![0.0f32; r];
    for (new_j, &old_j) in order.iter().enumerate() {
        s_sorted[new_j] = s[old_j];
        for i in 0..m {
            u_sorted.data[i * r + new_j] = u_final.data[i * r + old_j];
        }
        for i in 0..n {
            v_sorted.data[i * r + new_j] = v.data[i * r + old_j];
        }
    }
    TruncatedSvd {
        u: u_sorted,
        s: s_sorted,
        v: v_sorted,
    }
}

/// Frobenius norm.
pub fn fro_norm(w: &Tensor) -> f64 {
    w.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a matrix with a known decaying spectrum.
    fn spectral_matrix(m: usize, n: usize, decay: f32, seed: u64) -> Tensor {
        let r = m.min(n);
        let mut rng = Pcg64::new(seed);
        let mut u = Tensor::randn(&[m, r], 1.0, &mut rng);
        let mut v = Tensor::randn(&[n, r], 1.0, &mut rng);
        orthonormalize_cols(&mut u);
        orthonormalize_cols(&mut v);
        let mut us = u.clone();
        for i in 0..m {
            for j in 0..r {
                us.data[i * r + j] *= decay.powi(j as i32);
            }
        }
        matmul(&us, &v.transpose2())
    }

    #[test]
    fn svd_recovers_low_rank() {
        let w = spectral_matrix(24, 16, 0.3, 7); // fast decay -> effectively rank ~5
        let svd = truncated_svd(&w, 8, 20, 1);
        let approx = svd.reconstruct();
        let err = w
            .data
            .iter()
            .zip(&approx.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(err / fro_norm(&w) < 1e-2, "rel err {}", err / fro_norm(&w));
    }

    #[test]
    fn singular_values_decreasing() {
        let w = spectral_matrix(20, 20, 0.6, 3);
        let svd = truncated_svd(&w, 6, 15, 2);
        for j in 1..svd.s.len() {
            assert!(svd.s[j - 1] >= svd.s[j] - 1e-4);
        }
        // Leading singular value ≈ 1 (decay^0).
        assert!((svd.s[0] - 1.0).abs() < 0.05, "s0={}", svd.s[0]);
    }

    #[test]
    fn matvec_matches_reconstruct() {
        let w = spectral_matrix(12, 10, 0.5, 5);
        let svd = truncated_svd(&w, 4, 15, 9);
        let rec = svd.reconstruct();
        let mut rng = Pcg64::new(10);
        let x: Vec<f32> = (0..10).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0f32; 12];
        svd.matvec(&x, &mut y);
        // reference: rec @ x
        for i in 0..12 {
            let expect: f32 = (0..10).map(|j| rec.data[i * 10 + j] * x[j]).sum();
            assert!((y[i] - expect).abs() < 1e-4, "row {i}");
        }
    }

    #[test]
    fn matvec_subset_is_masked_matvec() {
        let w = spectral_matrix(8, 6, 0.7, 11);
        let svd = truncated_svd(&w, 3, 15, 12);
        let x: Vec<f32> = (0..6).map(|i| i as f32 * 0.5 - 1.0).collect();
        let subset = vec![1usize, 3, 4];
        let mut masked = vec![0.0f32; 6];
        for &c in &subset {
            masked[c] = x[c];
        }
        let mut y_subset = vec![0.0f32; 8];
        let mut y_masked = vec![0.0f32; 8];
        svd.matvec_subset(&x, &subset, &mut y_subset);
        svd.matvec(&masked, &mut y_masked);
        for i in 0..8 {
            assert!((y_subset[i] - y_masked[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn orthonormalize_produces_orthonormal() {
        let mut rng = Pcg64::new(21);
        let mut q = Tensor::randn(&[10, 4], 1.0, &mut rng);
        assert!(orthonormalize_cols(&mut q));
        for a in 0..4 {
            for b in 0..4 {
                let dot: f64 = (0..10)
                    .map(|i| q.data[i * 4 + a] as f64 * q.data[i * 4 + b] as f64)
                    .sum();
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-5, "({a},{b}) dot={dot}");
            }
        }
    }
}
