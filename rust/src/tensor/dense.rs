//! Dense row-major f32 tensor with up to 3 dimensions.
//!
//! The engine is deliberately simple: shapes are small (micro-models) and
//! everything hot lives in `sparse_kernel/` which operates on raw slices, so
//! this type optimizes for clarity and debuggability, not generality.

use crate::util::rng::Pcg64;

/// Row-major dense f32 tensor. `shape` has 1..=3 dims.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        assert!(!shape.is_empty() && shape.len() <= 3, "1..=3 dims");
        let n: usize = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} vs data len {}", data.len());
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    /// Gaussian init (used only in tests / synthetic weights).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Pcg64) -> Self {
        let n: usize = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.normal() as f32 * std).collect(),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.ndim(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn dims3(&self) -> (usize, usize, usize) {
        assert_eq!(self.ndim(), 3, "expected 3-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2])
    }

    /// Immutable row view of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let (r, c) = self.dims2();
        assert!(i < r);
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let (r, c) = self.dims2();
        assert!(i < r);
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        let (_, c) = self.dims2();
        self.data[i * c + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let (_, c) = self.dims2();
        self.data[i * c + j] = v;
    }

    /// Reshape without copying (numel must match).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D transpose (copying).
    pub fn transpose2(&self) -> Tensor {
        let (r, c) = self.dims2();
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Column L2 norms of a 2-D tensor with rows = output dim, cols = input
    /// dim; this is exactly `g_i = ||W[:,i]||_2` from Eq. 4 of the paper.
    pub fn col_l2_norms(&self) -> Vec<f32> {
        let (r, c) = self.dims2();
        let mut acc = vec![0.0f64; c];
        for i in 0..r {
            let row = &self.data[i * c..(i + 1) * c];
            for (j, &x) in row.iter().enumerate() {
                acc[j] += (x as f64) * (x as f64);
            }
        }
        acc.into_iter().map(|x| x.sqrt() as f32).collect()
    }

    /// Row L2 norms of a 2-D tensor.
    pub fn row_l2_norms(&self) -> Vec<f32> {
        let (r, _) = self.dims2();
        (0..r)
            .map(|i| {
                self.row(i)
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum::<f64>()
                    .sqrt() as f32
            })
            .collect()
    }

    /// Max |a - b| between two tensors of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Mean squared error vs another tensor.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(0, 2), 3.0);
        assert_eq!(t.at2(1, 0), 4.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::new(1);
        let t = Tensor::randn(&[5, 7], 1.0, &mut rng);
        assert_eq!(t.transpose2().transpose2(), t);
    }

    #[test]
    fn col_norms() {
        let t = Tensor::from_vec(&[2, 2], vec![3., 0., 4., 0.]);
        let g = t.col_l2_norms();
        assert!((g[0] - 5.0).abs() < 1e-6);
        assert_eq!(g[1], 0.0);
    }

    #[test]
    fn mse_and_maxdiff() {
        let a = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[1, 2], vec![1.5, 2.0]);
        assert!((a.mse(&b) - 0.125).abs() < 1e-9);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-7);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
