//! End-to-end serving driver (DESIGN.md §5, the required E2E example):
//! starts the full coordinator stack — HTTP front end, FIFO batcher,
//! continuous-batching scheduler, sparse engine — fires a concurrent
//! workload of real task prompts over TCP, and reports latency percentiles
//! and throughput, dense vs WiSparse-50%.
//!
//!     cargo run --release --example serve_e2e

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use wisparse::calib::{CalibSet, ModelCalib};
use wisparse::data::tasks::full_suite;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::allocator::{calibrate_wisparse, PipelineStages, WiSparseCfg};
use wisparse::sparsity::evo::EvoCfg;
use wisparse::sparsity::greedy::GreedyCfg;
use wisparse::sparsity::alpha_search::AlphaSearchCfg;
use wisparse::sparsity::methods::ScoredSparsifier;
use wisparse::sparsity::{Dense, Sparsifier};
use wisparse::util::stats::quantile;

fn http_post(addr: &str, path: &str, body: &str) -> anyhow::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line.split_whitespace().nth(1).unwrap_or("0").parse()?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut buf = vec![0u8; content_length];
    reader.read_exact(&mut buf)?;
    Ok((status, String::from_utf8_lossy(&buf).into_owned()))
}

/// POST a streaming request and reassemble the chunked NDJSON body.
fn http_post_chunked(addr: &str, path: &str, body: &str) -> anyhow::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line.split_whitespace().nth(1).unwrap_or("0").parse()?;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
    }
    let mut out = String::new();
    loop {
        let mut size_line = String::new();
        reader.read_line(&mut size_line)?;
        let size = usize::from_str_radix(size_line.trim(), 16)?;
        if size == 0 {
            break;
        }
        let mut buf = vec![0u8; size + 2]; // data + CRLF
        reader.read_exact(&mut buf)?;
        out.push_str(&String::from_utf8_lossy(&buf[..size]));
    }
    Ok((status, out))
}

fn run_workload(name: &str, model: Arc<Model>, sp: Arc<dyn Sparsifier>) -> anyhow::Result<f64> {
    // The production configuration: paged KV pool + radix prefix cache.
    let engine = Arc::new(Engine::paged(
        model,
        sp,
        EngineCfg::default(),
        &wisparse::kv::KvCfg::default(),
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 8,
                max_queue: 512,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    let sched_handle = std::thread::spawn(move || sched.run_scheduler());

    // HTTP front end on an ephemeral port.
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let http_coord = Arc::clone(&coord);
    std::thread::spawn(move || {
        let _ = wisparse::server::http::serve(http_coord, "127.0.0.1:0", move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv()?.to_string();
    println!("[{name}] listening on {addr}");

    // Workload: real task prompts, 4 concurrent clients x 12 requests.
    let suite = full_suite(12, 99);
    let prompts: Vec<String> = suite
        .iter()
        .flat_map(|t| t.items.iter().map(|i| i.prompt.clone()))
        .take(48)
        .collect();
    let t0 = std::time::Instant::now();
    let latencies: Vec<f64> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for chunk in prompts.chunks(prompts.len().div_ceil(4)) {
            let addr = addr.clone();
            handles.push(s.spawn(move || {
                let mut lats = Vec::new();
                for p in chunk {
                    let body = format!(r#"{{"prompt": {:?}, "max_new": 16}}"#, p);
                    let t = std::time::Instant::now();
                    let (status, _resp) = http_post(&addr, "/generate", &body).expect("request");
                    assert_eq!(status, 200, "bad status");
                    lats.push(t.elapsed().as_secs_f64() * 1e3);
                }
                lats
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    let total_tokens = 16.0 * prompts.len() as f64;
    let tput = total_tokens / wall;
    let (status, metrics) = http_post(&addr, "/generate", "not json")?;
    assert_eq!(status, 400, "error handling regressed: {metrics}");
    let pool = coord.metrics_json();
    let m = coord.metrics.lock().unwrap();
    println!(
        "[{name}] {} requests, wall {:.2}s -> {:.1} generated tok/s, density {:.3}",
        prompts.len(),
        wall,
        tput,
        m.density()
    );
    println!(
        "[{name}] kv pool: {}/{} blocks in use, prefix hit rate {:.3}",
        pool.get("blocks_in_use").as_f64().unwrap_or(0.0),
        pool.get("blocks_total").as_f64().unwrap_or(0.0),
        pool.get("prefix_hit_rate").as_f64().unwrap_or(0.0)
    );
    println!(
        "[{name}] latency p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms",
        quantile(&latencies, 0.5),
        quantile(&latencies, 0.9),
        quantile(&latencies, 0.99)
    );
    drop(m);
    // Per-token streaming: `"stream": true` must emit one NDJSON line per
    // accepted token plus a final done summary whose text equals their
    // concatenation.
    let (status, ndjson) = http_post_chunked(
        &addr,
        "/generate",
        r#"{"prompt": "stream check ", "max_new": 8, "stream": true}"#,
    )?;
    assert_eq!(status, 200, "streaming request failed");
    let lines: Vec<&str> = ndjson.lines().filter(|l| !l.is_empty()).collect();
    assert_eq!(lines.len(), 9, "8 token lines + done, got: {ndjson}");
    let mut streamed = String::new();
    for line in &lines[..8] {
        let j = wisparse::util::json::Json::parse(line).expect("token line is JSON");
        streamed.push_str(j.get("token").as_str().unwrap_or(""));
    }
    let done = wisparse::util::json::Json::parse(lines[8]).expect("done line is JSON");
    assert_eq!(done.get("done").as_bool(), Some(true));
    assert_eq!(done.get("text").as_str(), Some(streamed.as_str()));
    println!("[{name}] streaming: {} per-token lines ok", lines.len() - 1);
    coord.shutdown();
    // Unblock the accept loop with a dummy connection so the server thread
    // can observe the shutdown flag, then stop the scheduler.
    let _ = TcpStream::connect(&addr);
    sched_handle.join().ok();
    Ok(tput)
}

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts/models/llama-micro");
    let model = if dir.join("weights.bin").exists() {
        Arc::new(Model::load_dir(dir)?)
    } else {
        println!("(synthetic model — run `make artifacts` for the real one)");
        Arc::new(Model::synthetic(ModelConfig::preset("llama-micro")?, 5))
    };
    let calib_set = CalibSet::load(Path::new("artifacts/data/llama-micro/calib.json"))
        .unwrap_or_else(|_| CalibSet::synthetic(6, 64, 256, 3));
    let calib = ModelCalib::collect(&model, &calib_set.subset(6, 64));
    let cfg = WiSparseCfg {
        evo: EvoCfg { generations: 4, offspring: 8, eps: 0.05, ..EvoCfg::default() },
        greedy: GreedyCfg { step: 0.1, ..GreedyCfg::default() },
        alpha: AlphaSearchCfg { n_grid: 6, ..AlphaSearchCfg::default() },
    };
    let plan = calibrate_wisparse(&model, &calib, 0.5, &cfg, PipelineStages::FULL);
    let sparse: Arc<dyn Sparsifier> =
        Arc::new(ScoredSparsifier::from_plan("wisparse", &model, &plan));

    let dense_tput = run_workload("dense", Arc::clone(&model), Arc::new(Dense))?;
    let sparse_tput = run_workload("wisparse-50", model, sparse)?;
    println!(
        "\nend-to-end speedup at 50% sparsity: {:.1}% (paper: 17.2-21.4%)",
        (sparse_tput / dense_tput - 1.0) * 100.0
    );
    Ok(())
}
