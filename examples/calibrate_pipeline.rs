//! Walkthrough of the full WiSparse calibration pipeline (Alg. 1), printing
//! what each stage decides — the "how does the search actually behave"
//! example.
//!
//!     cargo run --release --example calibrate_pipeline

use std::path::Path;
use wisparse::calib::{CalibSet, ModelCalib};
use wisparse::eval::kl::mean_token_kl;
use wisparse::model::layers::{LayerId, LayerKind};
use wisparse::model::transformer::{ForwardStats, Model};
use wisparse::model::ModelConfig;
use wisparse::sparsity::alpha_search::{search_block_alphas, AlphaSearchCfg};
use wisparse::sparsity::evo::{allocation_loss, evolutionary_block_allocation, EvoCfg};
use wisparse::sparsity::greedy::{greedy_layer_allocation, GreedyCfg};

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts/models/llama-micro");
    let model = if dir.join("weights.bin").exists() {
        Model::load_dir(dir)?
    } else {
        println!("(synthetic model — run `make artifacts` for the real one)");
        Model::synthetic(ModelConfig::preset("llama-micro")?, 9)
    };
    let calib_set = CalibSet::load(Path::new("artifacts/data/llama-micro/calib.json"))
        .unwrap_or_else(|_| CalibSet::synthetic(6, 64, 256, 11));
    println!("== capture ==");
    let calib = ModelCalib::collect(&model, &calib_set.subset(6, 64));
    println!(
        "captured {} blocks x {} calib tokens",
        calib.blocks.len(),
        calib.blocks[0].inputs.shape[0]
    );

    println!("\n== stage 1: coarse (evolutionary block allocation, Alg. 3) ==");
    let target = 0.5;
    let uniform_loss = allocation_loss(&model, &calib, &vec![target; model.cfg.n_layers], 1.0);
    println!("uniform 50% loss (Eq. 8 KL): {uniform_loss:.5}");
    let evo_cfg = EvoCfg {
        generations: 8,
        offspring: 8,
        eps: 0.04,
        ..EvoCfg::default()
    };
    let (blocks, trace) = evolutionary_block_allocation(&model, &calib, target, &evo_cfg);
    for t in trace.iter().step_by(2) {
        println!("  gen {:>3}: best KL {:.5}", t.generation, t.best_loss);
    }
    println!(
        "block sparsities: {:?}",
        blocks.iter().map(|p| format!("{:.2}", p)).collect::<Vec<_>>()
    );

    println!("\n== stage 2: fine (greedy intra-block allocation, Alg. 4) ==");
    let greedy_cfg = GreedyCfg {
        step: 0.1,
        ..GreedyCfg::default()
    };
    let per_kind = greedy_layer_allocation(&model, 0, &calib.blocks[0], blocks[0], &greedy_cfg);
    for (i, &kind) in LayerKind::ALL.iter().enumerate() {
        println!("  block 0 {:<10} -> {:.2}", kind.name(), per_kind[i]);
    }

    println!("\n== stage 3: weight exponents (Alg. 2 grid search) ==");
    let alpha_cfg = AlphaSearchCfg {
        n_grid: 10,
        ..AlphaSearchCfg::default()
    };
    let keep: [f64; 7] = std::array::from_fn(|i| 1.0 - per_kind[i]);
    let result = search_block_alphas(&model, 0, &calib.blocks[0], &keep, &alpha_cfg);
    for (i, &kind) in LayerKind::ALL.iter().enumerate() {
        println!("  block 0 {:<10} alpha* = {:.2}", kind.name(), result.alphas[i]);
    }
    println!("  block 0 output MSE at optimum: {:.4e}", result.mse);

    println!("\n== end-to-end check ==");
    let plan = wisparse::sparsity::allocator::calibrate_wisparse(
        &model,
        &calib,
        target,
        &wisparse::sparsity::allocator::WiSparseCfg {
            evo: evo_cfg,
            greedy: greedy_cfg,
            alpha: alpha_cfg,
        },
        wisparse::sparsity::allocator::PipelineStages::FULL,
    );
    let sp = wisparse::sparsity::methods::ScoredSparsifier::from_plan("wisparse", &model, &plan);
    let mut stats = ForwardStats::default();
    let mut kl = 0.0;
    for (seq, dense_logits) in calib.seqs.iter().zip(&calib.dense_logits) {
        let sparse_logits = model.forward_seq(seq, &sp, &mut stats, None);
        kl += mean_token_kl(dense_logits, &sparse_logits);
    }
    println!(
        "final plan: effective sparsity {:.3}, achieved density {:.3}, calib KL {:.5} (uniform was {:.5})",
        plan.effective_sparsity(&model.cfg),
        stats.density(),
        kl / calib.seqs.len() as f64,
        uniform_loss
    );
    // Peek at two plan entries.
    for id in [LayerId::new(0, LayerKind::Up), LayerId::new(1, LayerKind::O)] {
        let lp = plan.layer(id);
        println!(
            "  {}: sparsity {:.2}, alpha {:.2}, tau {:.4}",
            id.key(),
            lp.sparsity,
            lp.alpha,
            lp.tau
        );
    }
    Ok(())
}
