//! Graceful-drain smoke test: SIGTERM mid-flight must not lose a single
//! response.
//!
//! Starts the full serving stack (paged engine, scheduler, HTTP front end,
//! SIGTERM handler), fires N concurrent `/generate` clients, raises SIGTERM
//! while they are in flight, and asserts the drain contract: every client
//! gets exactly one well-formed HTTP response (200 for work that finished,
//! 503/504 for work shed or expired by the drain), the scheduler and accept
//! loop both exit on their own, and the KV pool's leak counters balance.
//! Exits 0 only if all of that holds — CI runs this as the serve-drain
//! smoke.
//!
//!     cargo run --release --example drain_smoke

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::Dense;

const N_CLIENTS: usize = 6;

/// POST /generate, signalling on `sent` once the request bytes are on the
/// wire (so the main thread can SIGTERM with all clients in flight), then
/// read the response. Returns the status code.
fn post_generate(addr: &str, body: &str, sent: Sender<()>) -> anyhow::Result<u16> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "POST /generate HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let _ = sent.send(());
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow::anyhow!("bad status line {status_line:?}"))?
        .parse()?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut buf = vec![0u8; content_length];
    reader.read_exact(&mut buf)?;
    Ok(status)
}

fn main() -> anyhow::Result<()> {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano")?, 77));
    // Prefix cache off: after a drain the pool must be exactly empty, with
    // no cached blocks to account for.
    let engine = Arc::new(Engine::paged(
        model,
        Arc::new(Dense),
        EngineCfg {
            threads: 2,
            prefill_chunk: 16,
            ..EngineCfg::default()
        },
        &wisparse::kv::KvCfg {
            pool_blocks: 128,
            block_size: 8,
            prefix_cache: false,
        },
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 4,
                max_queue: 64,
            },
            drain_timeout: std::time::Duration::from_secs(10),
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    let sched_handle = std::thread::spawn(move || sched.run_scheduler());
    wisparse::server::install_sigterm_drain(Arc::clone(&coord));

    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let http_coord = Arc::clone(&coord);
    let serve_handle = std::thread::spawn(move || {
        wisparse::server::http::serve(http_coord, "127.0.0.1:0", move |a| {
            let _ = addr_tx.send(a);
        })
    });
    let addr = addr_rx.recv()?.to_string();
    println!("drain_smoke: serving on {addr}, {N_CLIENTS} clients");

    let (sent_tx, sent_rx) = std::sync::mpsc::channel();
    let clients: Vec<_> = (0..N_CLIENTS)
        .map(|i| {
            let addr = addr.clone();
            let sent = sent_tx.clone();
            std::thread::spawn(move || {
                let body = format!(r#"{{"prompt": "client {i} mid flight", "max_new": 48}}"#);
                post_generate(&addr, &body, sent)
            })
        })
        .collect();
    drop(sent_tx);
    // Every request is on the wire before the signal: the drain then owes
    // every one of them a response.
    for _ in 0..N_CLIENTS {
        sent_rx.recv()?;
    }
    std::thread::sleep(std::time::Duration::from_millis(30));

    #[cfg(unix)]
    {
        extern "C" {
            fn raise(sig: i32) -> i32;
        }
        const SIGTERM: i32 = 15;
        println!("drain_smoke: raising SIGTERM mid-flight");
        unsafe {
            raise(SIGTERM);
        }
    }
    #[cfg(not(unix))]
    coord.drain();

    let mut by_status = std::collections::BTreeMap::new();
    for (i, c) in clients.into_iter().enumerate() {
        let status = c
            .join()
            .expect("client thread panicked")
            .unwrap_or_else(|e| panic!("client {i} got no response: {e}"));
        assert!(
            matches!(status, 200 | 503 | 504),
            "client {i}: unexpected status {status}"
        );
        *by_status.entry(status).or_insert(0usize) += 1;
    }
    println!("drain_smoke: all {N_CLIENTS} clients answered: {by_status:?}");

    // The drain must wind the whole stack down on its own.
    sched_handle
        .join()
        .expect("scheduler thread panicked instead of draining");
    assert!(coord.scheduler_exited(), "scheduler did not exit after drain");
    serve_handle
        .join()
        .expect("serve thread panicked")
        .expect("serve loop errored");

    let kv = coord.engine().kv.as_ref().expect("paged engine");
    let (allocs, frees) = kv.pool().counters();
    assert_eq!(allocs, frees, "KV pool leak: {allocs} allocs vs {frees} frees");
    assert_eq!(kv.blocks_in_use(), 0, "KV blocks still held after drain");

    let m = coord.metrics.lock().unwrap();
    println!(
        "drain_smoke: ok — drain took {:.1} ms, shed {} / deadline {} / panics {}, pool {}={} alloc/free",
        m.drain_duration_ms, m.shed_total, m.deadline_exceeded_total, m.panics_caught_total, allocs, frees
    );
    Ok(())
}
