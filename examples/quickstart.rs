//! Quickstart: calibrate a WiSparse plan and compare dense vs sparse
//! generation on one model.
//!
//!     cargo run --release --example quickstart
//!
//! Uses trained artifacts when present (`make artifacts`), otherwise falls
//! back to a synthetic model so the example always runs.

use std::path::Path;
use std::sync::Arc;
use wisparse::calib::{CalibSet, ModelCalib};
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::sparsity::allocator::{calibrate_wisparse, PipelineStages, WiSparseCfg};
use wisparse::sparsity::evo::EvoCfg;
use wisparse::sparsity::greedy::GreedyCfg;
use wisparse::sparsity::alpha_search::AlphaSearchCfg;
use wisparse::sparsity::methods::ScoredSparsifier;
use wisparse::sparsity::Dense;

fn main() -> anyhow::Result<()> {
    // 1. Load a model (trained if available).
    let dir = Path::new("artifacts/models/llama-micro");
    let model = if dir.join("weights.bin").exists() {
        println!("loading trained llama-micro from {}", dir.display());
        Arc::new(Model::load_dir(dir)?)
    } else {
        println!("no artifacts — using a synthetic model (run `make artifacts` for real output)");
        Arc::new(Model::synthetic(ModelConfig::preset("llama-micro")?, 1))
    };

    // 2. Calibrate a 50% WiSparse plan (quick budget).
    let calib_path = Path::new("artifacts/data/llama-micro/calib.json");
    let calib_set = CalibSet::load(calib_path)
        .unwrap_or_else(|_| CalibSet::synthetic(6, 64, model.cfg.vocab_size, 3));
    println!("collecting calibration activations...");
    let calib = ModelCalib::collect(&model, &calib_set.subset(6, 64));
    let cfg = WiSparseCfg {
        evo: EvoCfg { generations: 5, offspring: 8, eps: 0.05, ..EvoCfg::default() },
        greedy: GreedyCfg { step: 0.1, ..GreedyCfg::default() },
        alpha: AlphaSearchCfg { n_grid: 8, ..AlphaSearchCfg::default() },
    };
    println!("running the WiSparse pipeline (Alg. 1) at 50% sparsity...");
    let plan = calibrate_wisparse(&model, &calib, 0.5, &cfg, PipelineStages::FULL);
    println!(
        "plan: effective sparsity {:.3}, block allocation {:?}",
        plan.effective_sparsity(&model.cfg),
        plan.block_sparsity
            .iter()
            .map(|p| (p * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );

    // 3. Generate with both engines.
    let sparse = Arc::new(ScoredSparsifier::from_plan("wisparse", &model, &plan));
    let dense_engine = Engine::new(Arc::clone(&model), Arc::new(Dense), EngineCfg::default());
    let sparse_engine = Engine::new(Arc::clone(&model), sparse, EngineCfg::default());
    for prompt in ["12+34=", "the capital of avaria is ", "rev(abc)="] {
        let (d_text, d_stats) = dense_engine.run_to_completion(prompt, 12, Sampling::Greedy);
        let (s_text, s_stats) = sparse_engine.run_to_completion(prompt, 12, Sampling::Greedy);
        println!(
            "prompt {prompt:?}\n  dense   (density {:.2}): {:?}\n  wisparse(density {:.2}): {:?}",
            d_stats.density(),
            d_text,
            s_stats.density(),
            s_text
        );
    }
    Ok(())
}
