//! Reproduces Observation 2 (Fig 3) in miniature: per-block sparsity
//! sensitivity is heterogeneous and non-monotonic in depth.
//!
//!     cargo run --release --example sensitivity_sweep

use std::path::Path;
use wisparse::calib::{CalibSet, ModelCalib};
use wisparse::data::corpus::CorpusGen;
use wisparse::eval::ppl::{delta_ppl_percent, perplexity};
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::sparsity::evo::sparsifier_for_allocation;
use wisparse::sparsity::Dense;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts/models/llama-micro");
    let model = if dir.join("weights.bin").exists() {
        Model::load_dir(dir)?
    } else {
        println!("(synthetic model — run `make artifacts` for the real one)");
        Model::synthetic(ModelConfig::preset("llama-micro")?, 21)
    };
    let calib_set = CalibSet::load(Path::new("artifacts/data/llama-micro/calib.json"))
        .unwrap_or_else(|_| CalibSet::synthetic(6, 64, 256, 23));
    let calib = ModelCalib::collect(&model, &calib_set.subset(6, 64));
    let eval: Vec<Vec<usize>> = CorpusGen::new(0xE7A1).calib_sequences(5, 80);
    let dense_ppl = perplexity(&model, &eval, &Dense);
    println!("dense perplexity: {dense_ppl:.3}\n");
    println!("{:<7} {:>10} {:>10}", "block", "ΔPPL@40%", "ΔPPL@50%");
    let n = model.cfg.n_layers;
    let mut deltas50 = Vec::new();
    for b in 0..n {
        let mut row = format!("{b:<7}");
        for level in [0.4, 0.5] {
            let mut alloc = vec![0.0; n];
            alloc[b] = level;
            let sp = sparsifier_for_allocation(&model, &calib, &alloc, 1.0);
            let d = delta_ppl_percent(dense_ppl, perplexity(&model, &eval, &sp));
            row.push_str(&format!(" {d:>9.2}%"));
            if level == 0.5 {
                deltas50.push(d);
            }
        }
        println!("{row}");
    }
    let max_b = deltas50
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    let min_b = deltas50
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!(
        "\nmost fragile block: {} (ΔPPL {:.2}%), most robust: {} (ΔPPL {:.2}%)",
        max_b.0, max_b.1, min_b.0, min_b.1
    );
    println!("-> heterogeneous sensitivity is exactly why WiSparse allocates per block (Sec 4.3)");
    Ok(())
}
