#!/usr/bin/env python3
"""Committed perf trajectory: an append-only per-commit record of the
headline numbers from the BENCH_*.json reports.

    python3 scripts/trajectory.py append --commit <sha> [--date YYYY-MM-DD]
    python3 scripts/trajectory.py check

`append` scans the working directory for BENCH_*.json files (written by
`cargo bench`) and appends one CSV row per headline metric to
results/trajectory/trajectory.csv. CI runs it on main after the bench jobs.

`check` compares the same headline metrics of the current BENCH_*.json
files against the most recent committed row per (bench, metric) and exits
nonzero on a >10% regression in the metric's bad direction. Only
runner-independent ratios (speedups, acceptance/hit rates, KL) gate;
absolute tok/s and GB/s are recorded as `info` for plotting but never fail
the build, because they track the runner's hardware as much as the code.

Stdlib only; no third-party imports.
"""

import argparse
import csv
import datetime
import json
import os
import sys

CSV_PATH = os.path.join("results", "trajectory", "trajectory.csv")
HEADER = ["commit", "date", "bench", "metric", "value"]
REGRESSION_TOLERANCE = 0.10

# Direction per gated metric: "up" = higher is better (gate on drops),
# "down" = lower is better (gate on rises), "info" = record only.


def _kernel_headline(r):
    rows = []
    for shape in r.get("shapes", []):
        label = str(shape.get("label", "?")).replace(" ", "_").replace("/", "-")
        backends = shape.get("backends", [])
        if not backends:
            continue
        rows.append(
            (
                f"{label}.best_speedup",
                max(b.get("speedup_vs_scalar", 0.0) for b in backends),
                "up",
            )
        )
        rows.append(
            (
                f"{label}.best_tok_s",
                max(b.get("tokens_per_s", 0.0) for b in backends),
                "info",
            )
        )
    obs = r.get("obs_sink")
    if obs:
        rows.append(
            ("obs_recording_overhead_pct", obs.get("recording_overhead_pct", 0.0), "info")
        )
    # Batch-fused decode scaling curve: the per-batch-size fused tok/s is
    # runner-bound (info), but the fused-vs-per-sequence speedup at batch 8
    # is a ratio and gates like the kernel speedups do.
    scaling = r.get("batch_scaling", {})
    for row in scaling.get("rows", []):
        b = int(row.get("batch", 0))
        rows.append((f"fused_batch{b}.tok_s", row.get("fused_tok_s", 0.0), "info"))
        rows.append(
            (
                f"fused_batch{b}.speedup_vs_per_seq",
                row.get("speedup", 0.0),
                "up" if b >= 8 else "info",
            )
        )
    # Shadow-dense sampling decode overhead at the default 1-in-100 rate.
    # Timing-noise-bound, so info only; the hard <2% gate lives in the CI
    # quality job against the same report.
    shadow = r.get("shadow_sampling")
    if shadow:
        rows.append(
            ("shadow_sampling_overhead_pct", shadow.get("overhead_pct", 0.0), "info")
        )
    return rows


def _quality_headline(r):
    """Headline shadow-dense drift metrics from the CI quality job's sparse
    profile smoke (a `wisparse profile --quality-sample-rate 1.0` report).

    The workload is deterministic (synthetic weights, fixed corpus seed,
    greedy sampling), so mean shadow-KL is a code property, not a runner
    property: it gates. max_kl is a single-sample extreme and stays info.
    """
    q = r.get("quality")
    if not q:
        return []
    return [
        ("shadow_mean_kl", q.get("mean_kl", 0.0), "down"),
        ("shadow_max_kl", q.get("max_kl", 0.0), "info"),
        ("shadow_top1_agreement", q.get("top1_agreement", 0.0), "up"),
        ("shadow_samples", q.get("samples", 0.0), "info"),
    ]


def _keyed_headline(spec):
    def extract(r):
        return [(metric, r[key], d) for metric, key, d in spec if key in r]

    return extract


def _serve_headline(r):
    rows = _keyed_headline(
        [
            ("prefill_speedup", "prefill_speedup", "up"),
            ("prefix_hit_rate", "prefix_hit_rate", "up"),
            ("e2e_tok_s_prefix_on", "e2e_tok_s_prefix_on", "info"),
        ]
    )(r)
    # Sharded-reactor A/B: absolute tok/s per replica count is runner-bound
    # (info), but the speedup over one replica and each fleet's prefix hit
    # rate are ratios of same-runner runs, so they gate at >=2 replicas.
    for row in r.get("replica_scaling", []):
        n = int(row.get("replicas", 0))
        gate = "up" if n >= 2 else "info"
        rows.append((f"replica{n}.tok_s", row.get("tok_s", 0.0), "info"))
        rows.append((f"replica{n}.speedup_vs_1", row.get("speedup_vs_1", 0.0), gate))
        rows.append(
            (f"replica{n}.prefix_hit_rate", row.get("prefix_hit_rate", 0.0), gate)
        )
    return rows


HEADLINES = {
    "BENCH_kernel.json": ("kernel", _kernel_headline),
    "BENCH_serve.json": ("serve", _serve_headline),
    "BENCH_quant.json": (
        "quant",
        _keyed_headline(
            [
                ("int8_speedup_sparse", "int8_speedup_sparse", "up"),
                ("int4_speedup_sparse", "int4_speedup_sparse", "up"),
                ("int8_kl", "int8_kl", "down"),
                ("int8_compression", "int8_compression", "info"),
            ]
        ),
    ),
    "BENCH_prefill.json": (
        "prefill",
        _keyed_headline(
            [
                ("prefill_speedup", "prefill_speedup", "up"),
                ("decode_gap_ratio", "decode_gap_ratio", "up"),
            ]
        ),
    ),
    "BENCH_spec.json": (
        "spec",
        _keyed_headline(
            [
                ("speedup", "speedup", "up"),
                ("acceptance_rate", "acceptance_rate", "up"),
            ]
        ),
    ),
    "BENCH_quality.json": ("quality", _quality_headline),
}


def current_metrics():
    """[(bench, metric, value, direction)] for every BENCH report present."""
    out = []
    for fname, (bench, extract) in sorted(HEADLINES.items()):
        if not os.path.exists(fname):
            continue
        with open(fname) as f:
            report = json.load(f)
        for metric, value, direction in extract(report):
            out.append((bench, metric, float(value), direction))
    return out


def cmd_append(args):
    metrics = current_metrics()
    if not metrics:
        print("trajectory: no BENCH_*.json in cwd, nothing to append")
        return 0
    date = args.date or datetime.date.today().isoformat()
    os.makedirs(os.path.dirname(CSV_PATH), exist_ok=True)
    new_file = not os.path.exists(CSV_PATH)
    with open(CSV_PATH, "a", newline="") as f:
        w = csv.writer(f)
        if new_file:
            w.writerow(HEADER)
        for bench, metric, value, _d in metrics:
            w.writerow([args.commit, date, bench, metric, f"{value:.6g}"])
    print(f"trajectory: appended {len(metrics)} rows for {args.commit[:12]}")
    return 0


def last_committed():
    """(bench, metric) -> most recently appended value."""
    last = {}
    if not os.path.exists(CSV_PATH):
        return last
    with open(CSV_PATH, newline="") as f:
        for row in csv.DictReader(f):
            try:
                last[(row["bench"], row["metric"])] = float(row["value"])
            except (KeyError, TypeError, ValueError):
                continue
    return last


def cmd_check(_args):
    baseline = last_committed()
    if not baseline:
        print("trajectory: no committed baseline yet, passing")
        return 0
    metrics = current_metrics()
    if not metrics:
        print("trajectory: no BENCH_*.json in cwd, nothing to check")
        return 0
    failures = []
    for bench, metric, value, direction in metrics:
        prev = baseline.get((bench, metric))
        if prev is None or direction == "info" or prev <= 0:
            continue
        ratio = value / prev
        if direction == "up" and ratio < 1.0 - REGRESSION_TOLERANCE:
            failures.append((bench, metric, prev, value, ratio))
        elif direction == "down" and ratio > 1.0 + REGRESSION_TOLERANCE:
            failures.append((bench, metric, prev, value, ratio))
        else:
            print(f"ok   {bench}.{metric}: {prev:.4g} -> {value:.4g} ({ratio:.2f}x)")
    for bench, metric, prev, value, ratio in failures:
        print(
            f"FAIL {bench}.{metric}: {prev:.4g} -> {value:.4g} "
            f"({ratio:.2f}x, tolerance {REGRESSION_TOLERANCE:.0%})",
            file=sys.stderr,
        )
    if failures:
        return 1
    print("trajectory: no regressions beyond tolerance")
    return 0


def main():
    p = argparse.ArgumentParser(description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)
    a = sub.add_parser("append", help="append current BENCH_*.json headline rows")
    a.add_argument("--commit", required=True)
    a.add_argument("--date", default=None)
    a.set_defaults(fn=cmd_append)
    c = sub.add_parser("check", help="fail on >10% regression vs last committed row")
    c.set_defaults(fn=cmd_check)
    args = p.parse_args()
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
