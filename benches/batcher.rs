//! Coordinator/batching benchmark: serving throughput and per-step latency
//! as the continuous-batching width grows, plus the shared-prefix workload
//! that exercises the paged KV cache's radix-tree prefix sharing (N clients
//! behind one long common system prompt). A final section A/Bs the sharded
//! front end: keep-alive HTTP clients through the epoll reactor against 1
//! vs 2 engine replicas behind the prefix-affinity router. Writes
//! `results/bench_batcher.csv` and `BENCH_serve.json` (prefill tok/s with
//! the prefix cache on vs off, speedup, hit rate, and a `replica_scaling`
//! table) so future PRs can track the serving trajectory.
//!
//!     cargo bench --bench batcher

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use wisparse::kv::KvCfg;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::report::csv::{f, write_csv};
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::server::router::prefix_hash;
use wisparse::server::{Coordinator, CoordinatorCfg, ReactorCfg, Router, RouterCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::util::json::Json;
use wisparse::util::timer::Stopwatch;

/// A ~50%-density magnitude sparsifier (exact plan irrelevant here).
fn teal_sparsifier(model: &Model) -> Arc<ScoredSparsifier> {
    Arc::new(ScoredSparsifier::new(
        "teal",
        (0..model.cfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau: 0.45 })
            .collect(),
    ))
}

fn batch_width_sweep() -> Vec<Vec<String>> {
    let model = Arc::new(Model::synthetic(
        ModelConfig::preset("llama-micro").unwrap(),
        77,
    ));
    let sp = teal_sparsifier(&model);
    let n_requests = 24;
    let max_new = 24;
    let mut csv = Vec::new();
    println!("== continuous batching: {n_requests} requests x {max_new} new tokens ==");
    for max_batch in [1usize, 2, 4, 8, 16] {
        let engine = Arc::new(Engine::paged(
            Arc::clone(&model),
            sp.clone(),
            EngineCfg::default(),
            &KvCfg {
                pool_blocks: 512,
                block_size: 16,
                prefix_cache: false, // unique prompts; isolate batching
            },
        ));
        let coord = Coordinator::new(
            engine,
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch,
                    max_queue: 256,
                },
                ..CoordinatorCfg::default()
            },
        );
        let sched = Arc::clone(&coord);
        let handle = std::thread::spawn(move || sched.run_scheduler());
        let sw = Stopwatch::start();
        let rxs: Vec<_> = (0..n_requests)
            .map(|i| {
                coord
                    .submit(&format!("prompt number {i} padding"), max_new, Sampling::Greedy)
                    .expect("submit")
            })
            .collect();
        for rx in rxs {
            rx.recv().expect("completion");
        }
        let wall = sw.elapsed_secs();
        let tput = (n_requests * max_new) as f64 / wall;
        let m = coord.metrics.lock().unwrap();
        println!(
            "batch {max_batch:>2}: {tput:>8.1} tok/s  queue p50 {:>7.1} ms  total p50 {:>8.1} ms",
            m.queue_ms.percentile(0.5),
            m.total_ms.percentile(0.5),
        );
        csv.push(vec![
            max_batch.to_string(),
            f(tput),
            f(m.queue_ms.percentile(0.5)),
            f(m.total_ms.percentile(0.5)),
        ]);
        drop(m);
        coord.shutdown();
        handle.join().unwrap();
    }
    csv
}

struct SharedPrefixResult {
    prefill_tok_s: f64,
    e2e_tok_s: f64,
    hit_rate: f64,
    preemptions: f64,
}

/// N clients sharing a long common system prompt — the paged-KV headline
/// workload. `max_new` is kept tiny so wall time is prefill-dominated and
/// the prefill tok/s comparison is clean.
fn shared_prefix_run(
    model: &Arc<Model>,
    prefix_cache: bool,
    n_clients: usize,
    prefix_tokens: usize,
) -> SharedPrefixResult {
    let sp = teal_sparsifier(model);
    let engine = Arc::new(Engine::paged(
        Arc::clone(model),
        sp,
        EngineCfg::default(),
        &KvCfg {
            pool_blocks: 512,
            block_size: 16,
            prefix_cache,
        },
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: n_clients,
                max_queue: 256,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    let handle = std::thread::spawn(move || sched.run_scheduler());

    // One byte per token: a `prefix_tokens`-char system prompt.
    let system_prompt: String = (0..prefix_tokens)
        .map(|i| (b'a' + (i % 26) as u8) as char)
        .collect();
    let max_new = 2usize;
    let prompt_for = |i: usize| format!("{system_prompt} user {i:03} asks");

    // Warm the cache with one sequential request (its prefill publishes the
    // shared prefix blocks), then fire all clients concurrently.
    coord
        .submit_blocking(&prompt_for(999), max_new, Sampling::Greedy)
        .expect("warm request");
    let total_prompt_tokens: usize = (0..n_clients).map(|i| prompt_for(i).len()).sum();
    let sw = Stopwatch::start();
    let responses: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_clients)
            .map(|i| {
                let coord = Arc::clone(&coord);
                let prompt = prompt_for(i);
                s.spawn(move || {
                    coord
                        .submit_blocking(&prompt, max_new, Sampling::Greedy)
                        .expect("client request")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = sw.elapsed_secs();
    let generated: usize = responses.iter().map(|r| r.n_generated).sum();
    let m = coord.metrics_json();
    let hit_rate = m.get("prefix_hit_rate").as_f64().unwrap_or(0.0);
    let preemptions = m.get("preemptions_total").as_f64().unwrap_or(0.0);
    coord.shutdown();
    handle.join().unwrap();
    SharedPrefixResult {
        prefill_tok_s: total_prompt_tokens as f64 / wall,
        e2e_tok_s: (total_prompt_tokens + generated) as f64 / wall,
        hit_rate,
        preemptions,
    }
}

// ---------------------------------------------------------------------------
// Replica scaling: real HTTP through the epoll reactor
// ---------------------------------------------------------------------------

struct ReplicaScaling {
    tok_s: f64,
    p95_total_ms: f64,
    hit_rate: f64,
}

/// One POST /generate over an already-open keep-alive connection; returns
/// (generated tokens, server-reported total_ms).
fn http_generate(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    prompt: &str,
    max_new: usize,
) -> (usize, f64) {
    let body = format!(r#"{{"prompt": "{prompt}", "max_new": {max_new}}}"#);
    write!(
        writer,
        "POST /generate HTTP/1.1\r\nHost: b\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("http write");
    let mut status_line = String::new();
    reader.read_line(&mut status_line).expect("status line");
    assert!(status_line.contains("200"), "generate failed: {status_line}");
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).expect("header");
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().expect("content-length");
            }
        }
    }
    let mut buf = vec![0u8; content_length];
    reader.read_exact(&mut buf).expect("body");
    let j = Json::parse(std::str::from_utf8(&buf).expect("utf8")).expect("json body");
    (
        j.get("generated_tokens").as_usize().unwrap_or(0),
        j.get("total_ms").as_f64().unwrap_or(0.0),
    )
}

/// The group prefixes are salted so that under `balance_mod` replicas the
/// router's first-64-byte hash pins group g to replica g % balance_mod:
/// the A/B then measures replica parallelism, not hash luck. The same
/// prefixes are reused at every replica count (with one replica the pin is
/// moot — everything lands on replica 0).
fn balanced_group_prefix(g: usize, prefix_tokens: usize, balance_mod: usize) -> String {
    let pad: String = (0..prefix_tokens)
        .map(|i| (b'a' + ((i + 7 * g) % 26) as u8) as char)
        .collect();
    (0..1000)
        .map(|salt| format!("group {g:02}.{salt:03} {pad}"))
        .find(|p| prefix_hash(p, 64) % balance_mod as u64 == (g % balance_mod) as u64)
        .expect("salt search always terminates")
}

/// N single-threaded engine replicas behind the prefix-affinity router and
/// the epoll reactor, loaded by concurrent keep-alive HTTP clients each
/// pinned to its own shared-prefix group. Decode-heavy (`max_new` 16) so
/// the engines, not the socket layer, are the bottleneck being scaled.
fn replica_scaling_run(
    model: &Arc<Model>,
    n_replicas: usize,
    n_clients: usize,
    reqs_per_client: usize,
    prefix_tokens: usize,
    balance_mod: usize,
) -> ReplicaScaling {
    let max_new = 16usize;
    let mut replicas = Vec::with_capacity(n_replicas);
    let mut scheds = Vec::with_capacity(n_replicas);
    for r in 0..n_replicas {
        let engine = Arc::new(Engine::paged(
            Arc::clone(model),
            teal_sparsifier(model),
            EngineCfg {
                threads: 1,
                ..EngineCfg::default()
            },
            &KvCfg {
                pool_blocks: 512 / n_replicas,
                block_size: 16,
                prefix_cache: true,
            },
        ));
        let coord = Coordinator::new(
            engine,
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch: 8,
                    max_queue: 256,
                },
                replica_id: r,
                ..CoordinatorCfg::default()
            },
        );
        let sched = Arc::clone(&coord);
        scheds.push(std::thread::spawn(move || sched.run_scheduler()));
        replicas.push(coord);
    }
    let router = Router::new(replicas, RouterCfg::default());
    let (tx, rx) = std::sync::mpsc::channel();
    let rr = Arc::clone(&router);
    let serve = std::thread::spawn(move || {
        wisparse::server::reactor::serve(rr, "127.0.0.1:0", ReactorCfg::default(), move |a| {
            tx.send(a).unwrap();
        })
        .expect("reactor serve");
    });
    let addr = rx.recv().expect("bound addr").to_string();
    let prefixes: Vec<String> = (0..n_clients)
        .map(|g| balanced_group_prefix(g, prefix_tokens, balance_mod))
        .collect();

    // Warm each group's radix blocks on its affinity replica.
    for p in &prefixes {
        let stream = TcpStream::connect(&addr).expect("connect");
        let mut writer = stream.try_clone().expect("clone");
        let mut reader = BufReader::new(stream);
        http_generate(&mut writer, &mut reader, &format!("{p} warm"), max_new);
    }

    let sw = Stopwatch::start();
    let per_client: Vec<(usize, usize, Vec<f64>)> = std::thread::scope(|s| {
        prefixes
            .iter()
            .map(|prefix| {
                let addr = addr.clone();
                s.spawn(move || {
                    let stream = TcpStream::connect(&addr).expect("connect");
                    let mut writer = stream.try_clone().expect("clone");
                    let mut reader = BufReader::new(stream);
                    let mut prompt_tokens = 0usize;
                    let mut generated = 0usize;
                    let mut lat = Vec::with_capacity(reqs_per_client);
                    for i in 0..reqs_per_client {
                        let prompt = format!("{prefix} q{i:02}");
                        prompt_tokens += prompt.len();
                        let (n, ms) = http_generate(&mut writer, &mut reader, &prompt, max_new);
                        generated += n;
                        lat.push(ms);
                    }
                    (prompt_tokens, generated, lat)
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect()
    });
    let wall = sw.elapsed_secs();

    let prompt_tokens: usize = per_client.iter().map(|(p, _, _)| *p).sum();
    let generated: usize = per_client.iter().map(|(_, g, _)| *g).sum();
    let mut lats: Vec<f64> = per_client.into_iter().flat_map(|(_, _, l)| l).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p95 = lats[((lats.len() as f64 * 0.95) as usize).min(lats.len() - 1)];
    let hit_rate = router
        .metrics_json()
        .get("prefix_hit_rate")
        .as_f64()
        .unwrap_or(0.0);

    router.drain();
    for h in scheds {
        h.join().expect("scheduler thread");
    }
    serve.join().expect("serve thread");
    ReplicaScaling {
        tok_s: (prompt_tokens + generated) as f64 / wall,
        p95_total_ms: p95,
        hit_rate,
    }
}

fn main() {
    let csv = batch_width_sweep();
    write_csv(
        std::path::Path::new("results/bench_batcher.csv"),
        &["max_batch", "tokens_per_s", "queue_p50_ms", "total_p50_ms"],
        &csv,
    )
    .expect("csv");
    println!("-> results/bench_batcher.csv");

    // Shared-prefix workload: 8 clients, common 256-token system prompt.
    // max_seq is widened so prompt + generation fit beyond the prefix.
    let mut cfg = ModelConfig::preset("llama-micro").unwrap();
    cfg.max_seq = 512;
    let model = Arc::new(Model::synthetic(cfg, 77));
    let n_clients = 8;
    let prefix_tokens = 256;
    println!("== shared-prefix serving: {n_clients} clients, {prefix_tokens}-token common prompt ==");
    let off = shared_prefix_run(&model, false, n_clients, prefix_tokens);
    let on = shared_prefix_run(&model, true, n_clients, prefix_tokens);
    let speedup = on.prefill_tok_s / off.prefill_tok_s;
    println!(
        "prefix cache off: {:>8.1} prefill tok/s  (hit rate {:.3})",
        off.prefill_tok_s, off.hit_rate
    );
    println!(
        "prefix cache on : {:>8.1} prefill tok/s  (hit rate {:.3})  -> {speedup:.2}x",
        on.prefill_tok_s, on.hit_rate
    );
    // Replica scaling through the reactor: single-threaded engines, so the
    // A/B isolates what sharding buys. One run per replica count, same
    // balanced shared-prefix workload each time.
    let reqs_per_client = 4usize;
    let replica_counts = [1usize, 2];
    let balance_mod = *replica_counts.iter().max().unwrap();
    println!("== replica scaling: epoll reactor, {n_clients} keep-alive clients ==");
    let mut scaling_rows = Vec::new();
    let mut base_tok_s = 0.0f64;
    for r in replica_counts {
        let res = replica_scaling_run(
            &model,
            r,
            n_clients,
            reqs_per_client,
            prefix_tokens,
            balance_mod,
        );
        if r == 1 {
            base_tok_s = res.tok_s;
        }
        let speedup = res.tok_s / base_tok_s;
        println!(
            "replicas {r}: {:>8.1} tok/s  p95 {:>7.1} ms  hit rate {:.3}  -> {speedup:.2}x vs 1",
            res.tok_s, res.p95_total_ms, res.hit_rate
        );
        scaling_rows.push(Json::obj(vec![
            ("replicas", Json::Num(r as f64)),
            ("tok_s", Json::Num(res.tok_s)),
            ("p95_total_ms", Json::Num(res.p95_total_ms)),
            ("prefix_hit_rate", Json::Num(res.hit_rate)),
            ("speedup_vs_1", Json::Num(speedup)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("serve_shared_prefix".into())),
        ("n_clients", Json::Num(n_clients as f64)),
        ("prefix_tokens", Json::Num(prefix_tokens as f64)),
        ("prefill_tok_s_prefix_off", Json::Num(off.prefill_tok_s)),
        ("prefill_tok_s_prefix_on", Json::Num(on.prefill_tok_s)),
        ("prefill_speedup", Json::Num(speedup)),
        ("e2e_tok_s_prefix_off", Json::Num(off.e2e_tok_s)),
        ("e2e_tok_s_prefix_on", Json::Num(on.e2e_tok_s)),
        ("prefix_hit_rate", Json::Num(on.hit_rate)),
        ("preemptions_total", Json::Num(on.preemptions)),
        ("replica_scaling", Json::Arr(scaling_rows)),
    ]);
    std::fs::write("BENCH_serve.json", report.to_string_pretty()).expect("BENCH_serve.json");
    println!("-> BENCH_serve.json");
}
