//! Coordinator/batching benchmark: serving throughput and per-step latency
//! as the continuous-batching width grows, plus the shared-prefix workload
//! that exercises the paged KV cache's radix-tree prefix sharing (N clients
//! behind one long common system prompt). Writes `results/bench_batcher.csv`
//! and `BENCH_serve.json` (prefill tok/s with the prefix cache on vs off,
//! speedup, hit rate) so future PRs can track the serving trajectory.
//!
//!     cargo bench --bench batcher

use std::sync::Arc;
use wisparse::kv::KvCfg;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::report::csv::{f, write_csv};
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::util::json::Json;
use wisparse::util::timer::Stopwatch;

/// A ~50%-density magnitude sparsifier (exact plan irrelevant here).
fn teal_sparsifier(model: &Model) -> Arc<ScoredSparsifier> {
    Arc::new(ScoredSparsifier::new(
        "teal",
        (0..model.cfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau: 0.45 })
            .collect(),
    ))
}

fn batch_width_sweep() -> Vec<Vec<String>> {
    let model = Arc::new(Model::synthetic(
        ModelConfig::preset("llama-micro").unwrap(),
        77,
    ));
    let sp = teal_sparsifier(&model);
    let n_requests = 24;
    let max_new = 24;
    let mut csv = Vec::new();
    println!("== continuous batching: {n_requests} requests x {max_new} new tokens ==");
    for max_batch in [1usize, 2, 4, 8, 16] {
        let engine = Arc::new(Engine::paged(
            Arc::clone(&model),
            sp.clone(),
            EngineCfg::default(),
            &KvCfg {
                pool_blocks: 512,
                block_size: 16,
                prefix_cache: false, // unique prompts; isolate batching
            },
        ));
        let coord = Coordinator::new(
            engine,
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch,
                    max_queue: 256,
                },
                ..CoordinatorCfg::default()
            },
        );
        let sched = Arc::clone(&coord);
        let handle = std::thread::spawn(move || sched.run_scheduler());
        let sw = Stopwatch::start();
        let rxs: Vec<_> = (0..n_requests)
            .map(|i| {
                coord
                    .submit(&format!("prompt number {i} padding"), max_new, Sampling::Greedy)
                    .expect("submit")
            })
            .collect();
        for rx in rxs {
            rx.recv().expect("completion");
        }
        let wall = sw.elapsed_secs();
        let tput = (n_requests * max_new) as f64 / wall;
        let m = coord.metrics.lock().unwrap();
        println!(
            "batch {max_batch:>2}: {tput:>8.1} tok/s  queue p50 {:>7.1} ms  total p50 {:>8.1} ms",
            m.queue_ms.percentile(0.5),
            m.total_ms.percentile(0.5),
        );
        csv.push(vec![
            max_batch.to_string(),
            f(tput),
            f(m.queue_ms.percentile(0.5)),
            f(m.total_ms.percentile(0.5)),
        ]);
        drop(m);
        coord.shutdown();
        handle.join().unwrap();
    }
    csv
}

struct SharedPrefixResult {
    prefill_tok_s: f64,
    e2e_tok_s: f64,
    hit_rate: f64,
    preemptions: f64,
}

/// N clients sharing a long common system prompt — the paged-KV headline
/// workload. `max_new` is kept tiny so wall time is prefill-dominated and
/// the prefill tok/s comparison is clean.
fn shared_prefix_run(
    model: &Arc<Model>,
    prefix_cache: bool,
    n_clients: usize,
    prefix_tokens: usize,
) -> SharedPrefixResult {
    let sp = teal_sparsifier(model);
    let engine = Arc::new(Engine::paged(
        Arc::clone(model),
        sp,
        EngineCfg::default(),
        &KvCfg {
            pool_blocks: 512,
            block_size: 16,
            prefix_cache,
        },
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: n_clients,
                max_queue: 256,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    let handle = std::thread::spawn(move || sched.run_scheduler());

    // One byte per token: a `prefix_tokens`-char system prompt.
    let system_prompt: String = (0..prefix_tokens)
        .map(|i| (b'a' + (i % 26) as u8) as char)
        .collect();
    let max_new = 2usize;
    let prompt_for = |i: usize| format!("{system_prompt} user {i:03} asks");

    // Warm the cache with one sequential request (its prefill publishes the
    // shared prefix blocks), then fire all clients concurrently.
    coord
        .submit_blocking(&prompt_for(999), max_new, Sampling::Greedy)
        .expect("warm request");
    let total_prompt_tokens: usize = (0..n_clients).map(|i| prompt_for(i).len()).sum();
    let sw = Stopwatch::start();
    let responses: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_clients)
            .map(|i| {
                let coord = Arc::clone(&coord);
                let prompt = prompt_for(i);
                s.spawn(move || {
                    coord
                        .submit_blocking(&prompt, max_new, Sampling::Greedy)
                        .expect("client request")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = sw.elapsed_secs();
    let generated: usize = responses.iter().map(|r| r.n_generated).sum();
    let m = coord.metrics_json();
    let hit_rate = m.get("prefix_hit_rate").as_f64().unwrap_or(0.0);
    let preemptions = m.get("preemptions_total").as_f64().unwrap_or(0.0);
    coord.shutdown();
    handle.join().unwrap();
    SharedPrefixResult {
        prefill_tok_s: total_prompt_tokens as f64 / wall,
        e2e_tok_s: (total_prompt_tokens + generated) as f64 / wall,
        hit_rate,
        preemptions,
    }
}

fn main() {
    let csv = batch_width_sweep();
    write_csv(
        std::path::Path::new("results/bench_batcher.csv"),
        &["max_batch", "tokens_per_s", "queue_p50_ms", "total_p50_ms"],
        &csv,
    )
    .expect("csv");
    println!("-> results/bench_batcher.csv");

    // Shared-prefix workload: 8 clients, common 256-token system prompt.
    // max_seq is widened so prompt + generation fit beyond the prefix.
    let mut cfg = ModelConfig::preset("llama-micro").unwrap();
    cfg.max_seq = 512;
    let model = Arc::new(Model::synthetic(cfg, 77));
    let n_clients = 8;
    let prefix_tokens = 256;
    println!("== shared-prefix serving: {n_clients} clients, {prefix_tokens}-token common prompt ==");
    let off = shared_prefix_run(&model, false, n_clients, prefix_tokens);
    let on = shared_prefix_run(&model, true, n_clients, prefix_tokens);
    let speedup = on.prefill_tok_s / off.prefill_tok_s;
    println!(
        "prefix cache off: {:>8.1} prefill tok/s  (hit rate {:.3})",
        off.prefill_tok_s, off.hit_rate
    );
    println!(
        "prefix cache on : {:>8.1} prefill tok/s  (hit rate {:.3})  -> {speedup:.2}x",
        on.prefill_tok_s, on.hit_rate
    );
    let report = Json::obj(vec![
        ("bench", Json::Str("serve_shared_prefix".into())),
        ("n_clients", Json::Num(n_clients as f64)),
        ("prefix_tokens", Json::Num(prefix_tokens as f64)),
        ("prefill_tok_s_prefix_off", Json::Num(off.prefill_tok_s)),
        ("prefill_tok_s_prefix_on", Json::Num(on.prefill_tok_s)),
        ("prefill_speedup", Json::Num(speedup)),
        ("e2e_tok_s_prefix_off", Json::Num(off.e2e_tok_s)),
        ("e2e_tok_s_prefix_on", Json::Num(on.e2e_tok_s)),
        ("prefix_hit_rate", Json::Num(on.hit_rate)),
        ("preemptions_total", Json::Num(on.preemptions)),
    ]);
    std::fs::write("BENCH_serve.json", report.to_string_pretty()).expect("BENCH_serve.json");
    println!("-> BENCH_serve.json");
}
