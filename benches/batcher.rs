//! Coordinator/batching benchmark: serving throughput and per-step latency
//! as the continuous-batching width grows — the L3 scheduling contribution
//! in isolation (per-sequence dynamic masks, as the paper's limitation
//! section calls for).
//!
//!     cargo bench --bench batcher

use std::sync::Arc;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::report::csv::{f, write_csv};
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::util::timer::Stopwatch;

fn main() {
    let model = Arc::new(Model::synthetic(
        ModelConfig::preset("llama-micro").unwrap(),
        77,
    ));
    // A ~50%-density magnitude sparsifier (exact plan irrelevant here).
    let sp = Arc::new(ScoredSparsifier::new(
        "teal",
        (0..model.cfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau: 0.45 })
            .collect(),
    ));
    let n_requests = 24;
    let max_new = 24;
    let mut csv = Vec::new();
    println!("== continuous batching: {n_requests} requests x {max_new} new tokens ==");
    for max_batch in [1usize, 2, 4, 8, 16] {
        let engine = Arc::new(Engine::new(
            Arc::clone(&model),
            sp.clone(),
            EngineCfg::default(),
        ));
        let coord = Coordinator::new(
            engine,
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch,
                    max_queue: 256,
                },
            },
        );
        let sched = Arc::clone(&coord);
        let handle = std::thread::spawn(move || sched.run_scheduler());
        let sw = Stopwatch::start();
        let rxs: Vec<_> = (0..n_requests)
            .map(|i| {
                coord
                    .submit(&format!("prompt number {i} padding"), max_new, Sampling::Greedy)
                    .expect("submit")
            })
            .collect();
        for rx in rxs {
            rx.recv().expect("completion");
        }
        let wall = sw.elapsed_secs();
        let tput = (n_requests * max_new) as f64 / wall;
        let m = coord.metrics.lock().unwrap();
        println!(
            "batch {max_batch:>2}: {tput:>8.1} tok/s  queue p50 {:>7.1} ms  total p50 {:>8.1} ms",
            m.queue_ms.percentile(0.5),
            m.total_ms.percentile(0.5),
        );
        csv.push(vec![
            max_batch.to_string(),
            f(tput),
            f(m.queue_ms.percentile(0.5)),
            f(m.total_ms.percentile(0.5)),
        ]);
        drop(m);
        coord.shutdown();
        handle.join().unwrap();
    }
    write_csv(
        std::path::Path::new("results/bench_batcher.csv"),
        &["max_batch", "tokens_per_s", "queue_p50_ms", "total_p50_ms"],
        &csv,
    )
    .expect("csv");
    println!("-> results/bench_batcher.csv");
}
