//! Self-speculative decoding A/B bench: baseline decode tok/s vs
//! speculative decode (high-sparsity draft + layer-major production verify
//! chunk) on a deliberately memory-heavy synthetic model, where the verify
//! chunk's weight-stream amortization is the mechanism under test. Writes
//! `results/bench_spec.csv` (the sweep) and `BENCH_spec.json` (the A/B row
//! at the default config, plus a self-consistency sanity row that must hit
//! 100% acceptance).
//!
//!     cargo bench --bench spec_decode

use std::sync::Arc;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::report::csv::{f, write_csv};
use wisparse::server::engine::{Engine, EngineCfg, SpecCfg, SpecEngine};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::sparsity::Sparsifier;
use wisparse::util::json::Json;
use wisparse::util::timer::Stopwatch;

/// A wider/deeper profile than the paper presets so the projection weights
/// (~32 MB) dwarf typical L2: token-major decode re-streams the whole model
/// per token, which is exactly the regime speculative verify amortizes.
fn bench_config() -> ModelConfig {
    ModelConfig {
        name: "spec-bench".to_string(),
        vocab_size: 256,
        d_model: 256,
        n_layers: 10,
        n_heads: 4,
        ffn_dim: 704,
        max_seq: 192,
        rope_base: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

fn teal(model: &Model, tau: f32) -> Arc<dyn Sparsifier> {
    Arc::new(ScoredSparsifier::new(
        "teal",
        (0..model.cfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau })
            .collect(),
    ))
}

const PROMPTS: [&str; 3] = ["the quick brown fox ", "12 + 34 = ", "once upon a time "];
const MAX_NEW: usize = 96;

struct RunResult {
    tok_s: f64,
    density: f64,
    acceptance: f64,
    tokens_per_round: f64,
    texts: Vec<String>,
}

/// Baseline: plain sequential decode at production sparsity (prefill
/// excluded from the timed section).
fn baseline_run(engine: &Arc<Engine>) -> RunResult {
    let mut texts = Vec::new();
    let mut secs = 0.0f64;
    let mut tokens = 0usize;
    let mut density = 0.0f64;
    for (i, prompt) in PROMPTS.iter().enumerate() {
        let mut seq = engine.admit(i as u64, prompt, MAX_NEW, Sampling::Greedy);
        engine.prefill(&mut seq);
        let sw = Stopwatch::start();
        while !seq.finished() {
            engine.decode_one(&mut seq);
        }
        secs += sw.elapsed_secs();
        tokens += seq.generated.len();
        density += seq.stats.density();
        texts.push(seq.text());
    }
    RunResult {
        tok_s: tokens as f64 / secs,
        density: density / PROMPTS.len() as f64,
        acceptance: 0.0,
        tokens_per_round: 1.0,
        texts,
    }
}

/// Speculative: draft at `draft_tau`, verify at production sparsity in
/// layer-major chunks. Greedy output is asserted token-identical to the
/// baseline, so the bench doubles as an end-to-end differential smoke.
fn spec_run(engine: &Arc<Engine>, draft: Arc<dyn Sparsifier>, k: usize) -> RunResult {
    let spec = SpecEngine::new(
        Arc::clone(engine),
        draft,
        SpecCfg {
            k,
            ..SpecCfg::default()
        },
    );
    let mut texts = Vec::new();
    let mut secs = 0.0f64;
    let mut tokens = 0usize;
    let mut density = 0.0f64;
    let (mut drafted, mut accepted, mut rounds) = (0u64, 0u64, 0u64);
    for (i, prompt) in PROMPTS.iter().enumerate() {
        let mut seq = spec.admit(i as u64, prompt, MAX_NEW, Sampling::Greedy);
        spec.prefill(&mut seq);
        let sw = Stopwatch::start();
        while !seq.finished() {
            spec.spec_round(&mut seq);
        }
        secs += sw.elapsed_secs();
        tokens += seq.generated.len();
        density += seq.stats.density();
        drafted += seq.spec.drafted;
        accepted += seq.spec.accepted;
        rounds += seq.spec.rounds;
        texts.push(seq.text());
    }
    RunResult {
        tok_s: tokens as f64 / secs,
        density: density / PROMPTS.len() as f64,
        acceptance: if drafted == 0 {
            0.0
        } else {
            accepted as f64 / drafted as f64
        },
        tokens_per_round: tokens as f64 / rounds.max(1) as f64,
        texts,
    }
}

fn main() {
    let cfg = bench_config();
    println!(
        "== speculative decode: {} ({} params, {} prompts x {MAX_NEW} tokens) ==",
        cfg.name,
        cfg.n_params(),
        PROMPTS.len()
    );
    let model = Arc::new(Model::synthetic(cfg, 77));
    let prod_tau = 0.45f32; // the ~50%-density production config other benches use
    let prod = teal(&model, prod_tau);
    let engine = Arc::new(Engine::new(
        Arc::clone(&model),
        Arc::clone(&prod),
        EngineCfg::default(),
    ));
    let base = baseline_run(&engine);
    println!(
        "baseline          : {:>8.1} tok/s  (density {:.3})",
        base.tok_s, base.density
    );

    // Sweep: (draft tau, k). The first row is the self-consistency sanity
    // check (draft == production must be fully accepted); the (0.9, 4) row
    // is the default `--speculative` configuration.
    let sweep: [(f32, usize); 5] = [(prod_tau, 4), (0.9, 4), (0.9, 8), (1.3, 4), (1.3, 8)];
    let default_row = 1usize;
    let mut csv = Vec::new();
    let mut results = Vec::new();
    for &(draft_tau, k) in &sweep {
        let r = spec_run(&engine, teal(&model, draft_tau), k);
        for (a, b) in r.texts.iter().zip(&base.texts) {
            assert_eq!(a, b, "speculative decode diverged from baseline");
        }
        let speedup = r.tok_s / base.tok_s;
        println!(
            "spec tau={draft_tau:<4} k={k}: {:>8.1} tok/s  ({speedup:.2}x, accept {:.3}, {:.2} tok/round)",
            r.tok_s, r.acceptance, r.tokens_per_round
        );
        csv.push(vec![
            format!("{draft_tau}"),
            k.to_string(),
            f(r.tok_s),
            f(speedup),
            f(r.acceptance),
            f(r.tokens_per_round),
            f(r.density),
        ]);
        results.push(r);
    }
    assert!(
        results[0].acceptance > 0.999,
        "self-consistency: a draft identical to production must be fully \
         accepted (got {})",
        results[0].acceptance
    );
    write_csv(
        std::path::Path::new("results/bench_spec.csv"),
        &[
            "draft_tau",
            "k",
            "tokens_per_s",
            "speedup",
            "acceptance_rate",
            "tokens_per_round",
            "density",
        ],
        &csv,
    )
    .expect("csv");
    println!("-> results/bench_spec.csv");

    // Headline A/B row: default config vs baseline, plus the sanity row.
    let best = results
        .iter()
        .zip(&sweep)
        .max_by(|a, b| a.0.tok_s.partial_cmp(&b.0.tok_s).expect("finite"))
        .expect("nonempty sweep");
    let dflt = &results[default_row];
    let report = Json::obj(vec![
        ("bench", Json::Str("spec_decode".into())),
        ("model", Json::Str("spec-bench-d256-l10".into())),
        ("prompts", Json::Num(PROMPTS.len() as f64)),
        ("max_new", Json::Num(MAX_NEW as f64)),
        ("production_tau", Json::Num(prod_tau as f64)),
        ("draft_tau", Json::Num(sweep[default_row].0 as f64)),
        ("spec_k", Json::Num(sweep[default_row].1 as f64)),
        ("baseline_tok_s", Json::Num(base.tok_s)),
        ("spec_tok_s", Json::Num(dflt.tok_s)),
        ("speedup", Json::Num(dflt.tok_s / base.tok_s)),
        ("acceptance_rate", Json::Num(dflt.acceptance)),
        ("tokens_per_round", Json::Num(dflt.tokens_per_round)),
        ("sanity_acceptance_rate", Json::Num(results[0].acceptance)),
        ("best_tok_s", Json::Num(best.0.tok_s)),
        ("best_speedup", Json::Num(best.0.tok_s / base.tok_s)),
        ("best_draft_tau", Json::Num(best.1 .0 as f64)),
        ("best_k", Json::Num(best.1 .1 as f64)),
        ("greedy_output_identical", Json::Num(1.0)),
    ]);
    std::fs::write("BENCH_spec.json", report.to_string_pretty()).expect("BENCH_spec.json");
    println!("-> BENCH_spec.json");
}
