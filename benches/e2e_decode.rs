//! End-to-end decode benchmark (Fig 4-right / Sec 5.3): tokens/s for the
//! paper's protocol (200 tokens from a 5-token prompt) across methods at
//! 50% sparsity, on llama-micro. Uses trained artifacts if present.
//!
//!     cargo bench --bench e2e_decode

use std::path::Path;
use std::sync::Arc;
use wisparse::calib::{CalibSet, ModelCalib};
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::report::csv::{f, write_csv};
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::sparsity::allocator::{
    calibrate_rsparse, calibrate_teal, calibrate_wina, calibrate_wisparse, PipelineStages,
    WiSparseCfg,
};
use wisparse::sparsity::evo::EvoCfg;
use wisparse::sparsity::greedy::GreedyCfg;
use wisparse::sparsity::alpha_search::AlphaSearchCfg;
use wisparse::sparsity::methods::{RSparse, ScoredSparsifier};
use wisparse::sparsity::{Dense, Sparsifier};
use wisparse::util::timer::Stopwatch;

fn main() {
    let dir = Path::new("artifacts/models/llama-micro");
    let model = if dir.join("weights.bin").exists() {
        Arc::new(Model::load_dir(dir).expect("load model"))
    } else {
        eprintln!("(synthetic model — run `make artifacts` for trained weights)");
        Arc::new(Model::synthetic(
            ModelConfig::preset("llama-micro").unwrap(),
            33,
        ))
    };
    let calib_set = CalibSet::load(Path::new("artifacts/data/llama-micro/calib.json"))
        .unwrap_or_else(|_| CalibSet::synthetic(6, 64, 256, 35));
    let calib = ModelCalib::collect(&model, &calib_set.subset(6, 64));
    let cfg = WiSparseCfg {
        evo: EvoCfg { generations: 4, offspring: 8, eps: 0.05, ..EvoCfg::default() },
        greedy: GreedyCfg { step: 0.1, ..GreedyCfg::default() },
        alpha: AlphaSearchCfg { n_grid: 6, ..AlphaSearchCfg::default() },
    };
    let target = 0.5;
    // One wisparse plan shared by the SIMD row and its pre-SIMD A/B twin.
    let wisparse_plan = calibrate_wisparse(&model, &calib, target, &cfg, PipelineStages::FULL);
    let methods: Vec<(&str, Arc<dyn Sparsifier>)> = vec![
        ("dense", Arc::new(Dense)),
        ("rsparse", {
            let plan = calibrate_rsparse(&model, &calib, target);
            Arc::new(RSparse::from_plan(&model, &plan, 16))
        }),
        ("teal", {
            let plan = calibrate_teal(&model, &calib, target, &cfg.greedy);
            Arc::new(ScoredSparsifier::from_plan("teal", &model, &plan))
        }),
        ("wina", {
            let plan = calibrate_wina(&model, &calib, target);
            Arc::new(ScoredSparsifier::from_plan("wina", &model, &plan))
        }),
        ("wisparse-scalar", {
            // Same plan as `wisparse` below but forced through the pre-SIMD
            // kernels (x4 fused scored / scalar threshold) — the baseline
            // this PR's dispatched backend is measured against end to end.
            let sp = ScoredSparsifier::from_plan("wisparse", &model, &wisparse_plan);
            Arc::new(sp.force_scalar(true))
        }),
        ("wisparse", {
            Arc::new(ScoredSparsifier::from_plan("wisparse", &model, &wisparse_plan))
        }),
    ];
    let prompt = "aaaaa"; // 5 tokens, paper protocol
    let new_tokens = 200;
    let mut dense_tps = 0.0;
    let mut scalar_tps = 0.0;
    let mut simd_tps = 0.0;
    let mut csv = Vec::new();
    println!("== e2e decode: 200 tokens from a 5-token prompt (llama-micro) ==");
    for (name, sp) in methods {
        let engine = Engine::new(Arc::clone(&model), sp, EngineCfg::default());
        // warmup
        let _ = engine.run_to_completion(prompt, 32, Sampling::Greedy);
        let mut best = 0.0f64;
        let mut density = 1.0;
        for _ in 0..5 {
            let sw = Stopwatch::start();
            let (_, stats) = engine.run_to_completion(prompt, new_tokens, Sampling::Greedy);
            best = best.max(new_tokens as f64 / sw.elapsed_secs());
            density = stats.density();
        }
        if name == "dense" {
            dense_tps = best;
        } else if name == "wisparse-scalar" {
            scalar_tps = best;
        } else if name == "wisparse" {
            simd_tps = best;
        }
        println!(
            "{name:<10} density {density:.3}  {best:>8.1} tok/s  ({:+.1}% vs dense)",
            (best / dense_tps - 1.0) * 100.0
        );
        csv.push(vec![
            name.to_string(),
            f(target),
            f(density),
            f(best),
            f((best / dense_tps - 1.0) * 100.0),
        ]);
    }
    write_csv(
        Path::new("results/bench_e2e_decode.csv"),
        &["method", "target_sparsity", "density", "tokens_per_s", "speedup_pct"],
        &csv,
    )
    .expect("csv");
    println!("-> results/bench_e2e_decode.csv  (paper: +17.2% on Llama-3.1 at 50%)");
    if scalar_tps > 0.0 {
        println!(
            "SIMD dispatched kernels vs pre-SIMD path (same plan): {:+.1}% tokens/s",
            (simd_tps / scalar_tps - 1.0) * 100.0
        );
    }
}
