//! Chunked-prefill benchmark: the `BENCH_prefill.json` A/B.
//!
//! Part 1 — engine-level prefill throughput: one long prompt prefilled
//! token-by-token (`Engine::prefill_sequential`, the pre-chunking path:
//! every layer's weights stream from memory once per token and every
//! position pays an lm_head GEMV) versus chunked (`Engine::prefill`:
//! weights stream once per chunk, logits only for the final token). The
//! two paths are asserted bit-identical before timing is trusted.
//!
//! Part 2 — serving fairness: short sequences decode while a long prompt
//! arrives. With chunked prefill the scheduler interleaves one chunk per
//! iteration, so the decoders' inter-token gap (p95 of
//! `decode_gap_ms`) stays bounded; with a monolithic budget the same
//! prompt stalls every decoder for its entire prefill.
//!
//!     cargo bench --bench prefill

use std::sync::Arc;
use wisparse::kv::KvCfg;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::report::csv::{f, write_csv};
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::util::json::Json;
use wisparse::util::timer::Stopwatch;

/// A ~50%-density magnitude sparsifier (exact plan irrelevant here).
fn teal_sparsifier(model: &Model) -> Arc<ScoredSparsifier> {
    Arc::new(ScoredSparsifier::new(
        "teal",
        (0..model.cfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau: 0.45 })
            .collect(),
    ))
}

fn model() -> Arc<Model> {
    let mut cfg = ModelConfig::preset("llama-micro").unwrap();
    cfg.max_seq = 512;
    Arc::new(Model::synthetic(cfg, 77))
}

/// `n` one-byte tokens cycling the alphabet.
fn alpha_prompt(n: usize) -> String {
    (0..n).map(|i| (b'a' + (i % 26) as u8) as char).collect()
}

struct PrefillAb {
    chunked_tok_s: f64,
    sequential_tok_s: f64,
    bit_identical: bool,
}

/// Engine-level A/B over one long prompt; best of `reps`, logits compared
/// bitwise on every rep.
fn prefill_ab(model: &Arc<Model>, prompt_tokens: usize, chunk: usize, reps: usize) -> PrefillAb {
    let sp = teal_sparsifier(model);
    let engine = Engine::new(
        Arc::clone(model),
        sp,
        EngineCfg {
            prefill_chunk: chunk,
            threads: 1,
            ..EngineCfg::default()
        },
    );
    let prompt = alpha_prompt(prompt_tokens);
    let mut best_chunked = 0.0f64;
    let mut best_seq = 0.0f64;
    let mut bit_identical = true;
    for _ in 0..reps {
        let mut a = engine.admit(0, &prompt, 4, Sampling::Greedy);
        let sw = Stopwatch::start();
        engine.prefill(&mut a);
        best_chunked = best_chunked.max(prompt_tokens as f64 / sw.elapsed_secs());

        let mut b = engine.admit(1, &prompt, 4, Sampling::Greedy);
        let sw = Stopwatch::start();
        engine.prefill_sequential(&mut b);
        best_seq = best_seq.max(prompt_tokens as f64 / sw.elapsed_secs());

        let la = engine.last_logits(&a);
        let lb = engine.last_logits(&b);
        bit_identical &= la.len() == lb.len()
            && la.iter().zip(lb).all(|(x, y)| x.to_bits() == y.to_bits());
    }
    PrefillAb {
        chunked_tok_s: best_chunked,
        sequential_tok_s: best_seq,
        bit_identical,
    }
}

struct FairnessRun {
    decode_gap_p95_ms: f64,
    prefill_chunks: f64,
}

/// Short decoders co-running with several long prompts; returns the
/// decoders' observed p95 inter-token gap under the given prefill budget.
/// Several long prompts make the monolithic stall visible at the p95 (one
/// stall among ~100 decode steps would only surface at p99).
fn fairness_run(model: &Arc<Model>, prefill_chunk: usize, prompt_tokens: usize) -> FairnessRun {
    let sp = teal_sparsifier(model);
    let engine = Arc::new(Engine::paged(
        Arc::clone(model),
        sp,
        EngineCfg {
            prefill_chunk,
            threads: 2,
            ..EngineCfg::default()
        },
        &KvCfg {
            pool_blocks: 512,
            block_size: 16,
            prefix_cache: false,
        },
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 8,
                max_queue: 64,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    let handle = std::thread::spawn(move || sched.run_scheduler());
    // Two short-prompt decoders whose ~64 decode steps outlive every long
    // prompt's prefill, so most gap samples bracket prefill work. Five
    // long prompts put the monolithic stalls at >5% of the samples —
    // squarely above the p95 — while the chunked run spreads the same
    // work across every gap.
    let decoders: Vec<_> = (0..2)
        .map(|i| {
            coord
                .submit(&format!("short {i}"), 64, Sampling::Greedy)
                .expect("decoder submit")
        })
        .collect();
    // Let them take a few steps before the long prompts land.
    std::thread::sleep(std::time::Duration::from_millis(30));
    let long_prompt = alpha_prompt(prompt_tokens);
    let longs: Vec<_> = (0..5)
        .map(|i| {
            // Distinct tails: no prefix sharing shortcuts (cache is off
            // anyway), each prompt prefills in full.
            coord
                .submit(&format!("{long_prompt}{i}"), 2, Sampling::Greedy)
                .expect("long submit")
        })
        .collect();
    for rx in decoders {
        rx.recv().expect("decoder completion");
    }
    for rx in longs {
        rx.recv().expect("long completion");
    }
    let (p95, chunks) = {
        let m = coord.metrics.lock().unwrap();
        (m.decode_gap_ms.percentile(0.95), m.prefill_chunks_total as f64)
    };
    coord.shutdown();
    handle.join().unwrap();
    FairnessRun {
        decode_gap_p95_ms: p95,
        prefill_chunks: chunks,
    }
}

fn main() {
    let model = model();
    let prompt_tokens = 384usize;
    let chunk = 64usize;
    println!("== chunked vs token-by-token prefill: {prompt_tokens}-token prompt ==");
    let ab = prefill_ab(&model, prompt_tokens, chunk, 3);
    println!(
        "sequential: {:>8.1} prefill tok/s\nchunked   : {:>8.1} prefill tok/s  -> {:.2}x (bit-identical: {})",
        ab.sequential_tok_s,
        ab.chunked_tok_s,
        ab.chunked_tok_s / ab.sequential_tok_s,
        ab.bit_identical
    );
    assert!(ab.bit_identical, "chunked prefill diverged from sequential");

    println!("== decode fairness under a co-running {prompt_tokens}-token prefill ==");
    let chunked = fairness_run(&model, chunk, prompt_tokens);
    // A budget larger than any prompt = the old monolithic behaviour (the
    // whole prompt in one scheduler iteration).
    let mono = fairness_run(&model, usize::MAX / 2, prompt_tokens);
    println!(
        "decode gap p95: chunked {:.1} ms ({} chunks) vs monolithic {:.1} ms ({} chunks)",
        chunked.decode_gap_p95_ms,
        chunked.prefill_chunks,
        mono.decode_gap_p95_ms,
        mono.prefill_chunks
    );

    write_csv(
        std::path::Path::new("results/bench_prefill.csv"),
        &[
            "prompt_tokens",
            "chunk",
            "chunked_tok_s",
            "sequential_tok_s",
            "decode_gap_p95_ms_chunked",
            "decode_gap_p95_ms_monolithic",
        ],
        &[vec![
            prompt_tokens.to_string(),
            chunk.to_string(),
            f(ab.chunked_tok_s),
            f(ab.sequential_tok_s),
            f(chunked.decode_gap_p95_ms),
            f(mono.decode_gap_p95_ms),
        ]],
    )
    .expect("csv");
    println!("-> results/bench_prefill.csv");

    let report = Json::obj(vec![
        ("bench", Json::Str("prefill_chunking".into())),
        ("prompt_tokens", Json::Num(prompt_tokens as f64)),
        ("prefill_chunk", Json::Num(chunk as f64)),
        ("prefill_tok_s_chunked", Json::Num(ab.chunked_tok_s)),
        ("prefill_tok_s_sequential", Json::Num(ab.sequential_tok_s)),
        (
            "prefill_speedup",
            Json::Num(ab.chunked_tok_s / ab.sequential_tok_s),
        ),
        (
            "logits_bit_identical",
            Json::Num(if ab.bit_identical { 1.0 } else { 0.0 }),
        ),
        (
            "decode_gap_p95_ms_chunked",
            Json::Num(chunked.decode_gap_p95_ms),
        ),
        (
            "decode_gap_p95_ms_monolithic",
            Json::Num(mono.decode_gap_p95_ms),
        ),
        (
            "decode_gap_ratio",
            Json::Num(mono.decode_gap_p95_ms / chunked.decode_gap_p95_ms.max(1e-9)),
        ),
        ("prefill_chunks_total", Json::Num(chunked.prefill_chunks)),
    ]);
    std::fs::write("BENCH_prefill.json", report.to_string_pretty()).expect("BENCH_prefill.json");
    println!("-> BENCH_prefill.json");
}
