//! Quantized-weights decode A/B bench: dense-f32 vs int8 vs int4 at 0% and
//! ~50% activation sparsity on a deliberately memory-heavy synthetic model
//! (decode streams every projection's weights once per token, which is
//! exactly the traffic group quantization divides by 4x/8x). Writes
//! `results/bench_quant.csv` (all rows) and `BENCH_quant.json` (the A/B
//! summary the CI smoke job checks: tok/s, weight-GB/s, logits KL vs f32,
//! compression ratios).
//!
//!     cargo bench --bench quant_decode

use std::sync::Arc;
use wisparse::eval::kl::mean_token_kl;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::{ForwardStats, Model};
use wisparse::model::ModelConfig;
use wisparse::quant::QuantMode;
use wisparse::report::csv::{f, write_csv};
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::sparsity::Sparsifier;
use wisparse::util::json::Json;
use wisparse::util::timer::Stopwatch;

/// Same memory-heavy profile as the speculative bench: ~32 MB of f32
/// projection weights, so token-major decode is bandwidth-bound.
fn bench_config() -> ModelConfig {
    ModelConfig {
        name: "quant-bench".to_string(),
        vocab_size: 256,
        d_model: 256,
        n_layers: 10,
        n_heads: 4,
        ffn_dim: 704,
        max_seq: 192,
        rope_base: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

fn teal(model: &Model, tau: f32) -> Arc<dyn Sparsifier> {
    Arc::new(ScoredSparsifier::new(
        "teal",
        (0..model.cfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau })
            .collect(),
    ))
}

const PROMPTS: [&str; 3] = ["the quick brown fox ", "12 + 34 = ", "once upon a time "];
const MAX_NEW: usize = 96;
const REPS: usize = 2;
const GROUP: usize = 64;
/// tau 0.0 keeps everything (the 0%-sparsity row); 0.45 is the ~50%-density
/// production configuration other benches use.
const TAUS: [f32; 2] = [0.0, 0.45];

struct Row {
    repr: &'static str,
    tau: f32,
    tok_s: f64,
    gb_s: f64,
    density: f64,
    kl_vs_f32: f64,
    compression: f64,
}

/// Timed decode (prefill excluded), best of REPS.
fn decode_run(model: &Arc<Model>, sp: &Arc<dyn Sparsifier>) -> (f64, f64) {
    let engine = Engine::new(Arc::clone(model), Arc::clone(sp), EngineCfg::default());
    let mut best_tok_s = 0.0f64;
    let mut density = 1.0f64;
    for _ in 0..REPS {
        let mut secs = 0.0f64;
        let mut tokens = 0usize;
        let mut dsum = 0.0f64;
        for (i, prompt) in PROMPTS.iter().enumerate() {
            let mut seq = engine.admit(i as u64, prompt, MAX_NEW, Sampling::Greedy);
            engine.prefill(&mut seq);
            let sw = Stopwatch::start();
            while !seq.finished() {
                engine.decode_one(&mut seq);
            }
            secs += sw.elapsed_secs();
            tokens += seq.generated.len();
            dsum += seq.stats.density();
        }
        let tok_s = tokens as f64 / secs;
        if tok_s > best_tok_s {
            best_tok_s = tok_s;
            density = dsum / PROMPTS.len() as f64;
        }
    }
    (best_tok_s, density)
}

/// Teacher-forced logits for a fixed token sequence under a sparsifier.
fn forced_logits(model: &Model, tokens: &[usize], sp: &dyn Sparsifier) -> wisparse::tensor::Tensor {
    let mut stats = ForwardStats::default();
    model.forward_seq(tokens, sp, &mut stats, None)
}

fn main() {
    let cfg = bench_config();
    println!(
        "== quantized decode A/B: {} ({} params, {} prompts x {MAX_NEW} tokens, group {GROUP}) ==",
        cfg.name,
        cfg.n_params(),
        PROMPTS.len()
    );
    let f32_model = Arc::new(Model::synthetic(cfg, 99));
    let mut models: Vec<(&'static str, Arc<Model>)> = vec![("f32", Arc::clone(&f32_model))];
    for mode in [QuantMode::Int8, QuantMode::Int4] {
        let mut m = Model::synthetic(bench_config(), 99);
        m.quantize(mode, GROUP);
        models.push((mode.name(), Arc::new(m)));
    }

    // Fixed evaluation sequence for the KL columns: the f32 model's own
    // dense greedy continuation, teacher-forced through every repr.
    let mut stats = ForwardStats::default();
    let prompt_tokens: Vec<usize> = "the quick brown fox ".bytes().map(|b| b as usize).collect();
    let continuation =
        f32_model.generate_greedy(&prompt_tokens, 48, &wisparse::sparsity::Dense, &mut stats);
    let mut eval_tokens = prompt_tokens.clone();
    eval_tokens.extend(&continuation);
    // f32 reference logits per tau, computed once and shared by both
    // quantized reprs' KL columns.
    let f32_refs: Vec<(f32, wisparse::tensor::Tensor)> = TAUS
        .iter()
        .map(|&tau| {
            let sp = teal(&f32_model, tau);
            (tau, forced_logits(&f32_model, &eval_tokens, sp.as_ref()))
        })
        .collect();

    let mut rows: Vec<Row> = Vec::new();
    let mut csv = Vec::new();
    for (repr, model) in models.iter() {
        let repr: &'static str = *repr;
        let compression =
            model.weight_bytes_dense() as f64 / model.weight_bytes_resident() as f64;
        for &tau in &TAUS {
            let sp = teal(model, tau);
            let (tok_s, density) = decode_run(model, &sp);
            // Weight bytes actually streamed per token: kept fraction of
            // the block projections plus the always-dense lm_head.
            let lm_head_bytes = {
                use wisparse::quant::WeightRepr;
                model.lm_head.resident_bytes()
            };
            let proj_bytes = model.weight_bytes_resident() as f64
                - model.embed.numel() as f64 * 4.0
                - lm_head_bytes as f64;
            let bytes_per_token = proj_bytes * density + lm_head_bytes as f64;
            let gb_s = bytes_per_token * tok_s / 1e9;
            let kl_vs_f32 = if repr == "f32" {
                0.0
            } else {
                let (_, a) = f32_refs
                    .iter()
                    .find(|(t, _)| *t == tau)
                    .expect("reference computed for every tau");
                let b = forced_logits(model, &eval_tokens, sp.as_ref());
                mean_token_kl(a, &b)
            };
            println!(
                "{repr:>5} tau={tau:<4}: {tok_s:>8.1} tok/s  ({gb_s:.2} weight-GB/s, density {density:.3}, KL {kl_vs_f32:.5}, {compression:.2}x)",
            );
            csv.push(vec![
                repr.to_string(),
                format!("{tau}"),
                f(tok_s),
                f(gb_s),
                f(density),
                f(kl_vs_f32),
                f(compression),
            ]);
            rows.push(Row {
                repr,
                tau,
                tok_s,
                gb_s,
                density,
                kl_vs_f32,
                compression,
            });
        }
    }
    write_csv(
        std::path::Path::new("results/bench_quant.csv"),
        &[
            "repr",
            "tau",
            "tokens_per_s",
            "weight_gb_per_s",
            "density",
            "kl_vs_f32",
            "compression",
        ],
        &csv,
    )
    .expect("csv");
    println!("-> results/bench_quant.csv");

    let find = |repr: &str, tau: f32| -> &Row {
        rows.iter()
            .find(|r| r.repr == repr && r.tau == tau)
            .expect("row present")
    };
    let (f32_d, f32_s) = (find("f32", TAUS[0]), find("f32", TAUS[1]));
    let (i8_d, i8_s) = (find("int8", TAUS[0]), find("int8", TAUS[1]));
    let (i4_d, i4_s) = (find("int4", TAUS[0]), find("int4", TAUS[1]));
    let row_json = |r: &Row| {
        Json::obj(vec![
            ("repr", Json::Str(r.repr.to_string())),
            ("tau", Json::Num(r.tau as f64)),
            ("tok_s", Json::Num(r.tok_s)),
            ("weight_gb_s", Json::Num(r.gb_s)),
            ("density", Json::Num(r.density)),
            ("kl_vs_f32", Json::Num(r.kl_vs_f32)),
            ("compression", Json::Num(r.compression)),
        ])
    };
    let report = Json::obj(vec![
        ("bench", Json::Str("quant_decode".into())),
        ("model", Json::Str("quant-bench-d256-l10".into())),
        ("prompts", Json::Num(PROMPTS.len() as f64)),
        ("max_new", Json::Num(MAX_NEW as f64)),
        ("group", Json::Num(GROUP as f64)),
        ("sparse_tau", Json::Num(TAUS[1] as f64)),
        ("rows", Json::Arr(rows.iter().map(row_json).collect())),
        ("f32_dense_tok_s", Json::Num(f32_d.tok_s)),
        ("f32_sparse_tok_s", Json::Num(f32_s.tok_s)),
        ("int8_dense_tok_s", Json::Num(i8_d.tok_s)),
        ("int8_sparse_tok_s", Json::Num(i8_s.tok_s)),
        ("int4_dense_tok_s", Json::Num(i4_d.tok_s)),
        ("int4_sparse_tok_s", Json::Num(i4_s.tok_s)),
        ("int8_speedup_dense", Json::Num(i8_d.tok_s / f32_d.tok_s)),
        ("int8_speedup_sparse", Json::Num(i8_s.tok_s / f32_s.tok_s)),
        ("int4_speedup_sparse", Json::Num(i4_s.tok_s / f32_s.tok_s)),
        (
            "int8_ge_f32_at_equal_sparsity",
            Json::Num(if i8_d.tok_s >= f32_d.tok_s && i8_s.tok_s >= f32_s.tok_s {
                1.0
            } else {
                0.0
            }),
        ),
        ("int8_kl", Json::Num(i8_s.kl_vs_f32)),
        ("int4_kl", Json::Num(i4_s.kl_vs_f32)),
        ("int8_compression", Json::Num(i8_d.compression)),
        ("int4_compression", Json::Num(i4_d.compression)),
    ]);
    std::fs::write("BENCH_quant.json", report.to_string_pretty()).expect("BENCH_quant.json");
    println!("-> BENCH_quant.json");
    println!(
        "int8 vs f32: {:.2}x dense, {:.2}x at tau {} | int4: {:.2}x sparse",
        i8_d.tok_s / f32_d.tok_s,
        i8_s.tok_s / f32_s.tok_s,
        TAUS[1],
        i4_s.tok_s / f32_s.tok_s
    );
}
