//! Calibration-cost benchmark: wall-clock of each pipeline stage (capture,
//! Alg. 3 coarse, Alg. 4 fine, Alg. 2 alpha) — the "setup cost" the paper's
//! limitation section promises to reduce. Run on the nano profile so the
//! bench stays fast; ratios between stages are the interesting part.
//!
//!     cargo bench --bench calibration

use wisparse::calib::{CalibSet, ModelCalib};
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::report::csv::{f, write_csv};
use wisparse::sparsity::alpha_search::{search_alphas_into_plan, AlphaSearchCfg};
use wisparse::sparsity::evo::{evolutionary_block_allocation, EvoCfg};
use wisparse::sparsity::greedy::{greedy_layer_allocation, GreedyCfg};
use wisparse::sparsity::plan::SparsityPlan;
use wisparse::util::timer::Stopwatch;

fn main() {
    let model = Model::synthetic(ModelConfig::preset("nano").unwrap(), 55);
    let calib_set = CalibSet::synthetic(4, 48, model.cfg.vocab_size, 57);
    let mut csv = Vec::new();

    let sw = Stopwatch::start();
    let calib = ModelCalib::collect(&model, &calib_set);
    let t_capture = sw.elapsed_ms();
    println!("capture: {t_capture:.1} ms");
    csv.push(vec!["capture".into(), f(t_capture)]);

    let sw = Stopwatch::start();
    let evo_cfg = EvoCfg {
        generations: 10,
        offspring: 8,
        eps: 0.05,
        ..EvoCfg::default()
    };
    let (blocks, _) = evolutionary_block_allocation(&model, &calib, 0.5, &evo_cfg);
    let t_coarse = sw.elapsed_ms();
    println!(
        "coarse (Alg 3, {} gens x {} offspring): {t_coarse:.1} ms",
        evo_cfg.generations, evo_cfg.offspring
    );
    csv.push(vec!["coarse_evo".into(), f(t_coarse)]);

    let sw = Stopwatch::start();
    let greedy_cfg = GreedyCfg {
        step: 0.1,
        ..GreedyCfg::default()
    };
    for b in 0..model.cfg.n_layers {
        let _ = greedy_layer_allocation(&model, b, &calib.blocks[b], blocks[b], &greedy_cfg);
    }
    let t_fine = sw.elapsed_ms();
    println!("fine (Alg 4, all blocks): {t_fine:.1} ms");
    csv.push(vec!["fine_greedy".into(), f(t_fine)]);

    let sw = Stopwatch::start();
    let mut plan = SparsityPlan::uniform(&model.cfg, "bench", 0.5);
    let alpha_cfg = AlphaSearchCfg {
        n_grid: 10,
        ..AlphaSearchCfg::default()
    };
    search_alphas_into_plan(&model, &calib.blocks, &mut plan, &alpha_cfg);
    let t_alpha = sw.elapsed_ms();
    println!("alpha (Alg 2, {} grid pts): {t_alpha:.1} ms", alpha_cfg.n_grid);
    csv.push(vec!["alpha_grid".into(), f(t_alpha)]);

    let total = t_capture + t_coarse + t_fine + t_alpha;
    println!("total calibration: {total:.1} ms (nano profile)");
    csv.push(vec!["total".into(), f(total)]);
    write_csv(
        std::path::Path::new("results/bench_calibration.csv"),
        &["stage", "ms"],
        &csv,
    )
    .expect("csv");
    println!("-> results/bench_calibration.csv");
}
