//! Kernel microbenchmarks (Fig 4-left's mechanism): dense vs channel-
//! skipping GEMV on every distinct projection shape of the micro models,
//! across sparsity levels. Verifies the core claim that compute scales
//! ~linearly with kept channels and that scoring overhead is negligible.
//!
//!     cargo bench --bench kernel

use std::hint::black_box;
use std::sync::Arc;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::report::csv::{f, write_csv};
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::sparsity::Sparsifier;
use wisparse::sparse_kernel::gemv::{
    count_kept_scored, sparse_gemv_fused_parallel_with, sparse_gemv_fused_with,
};
use wisparse::sparse_kernel::{dense_gemv, simd, sparse_gemv_scored, ColMajorMatrix};
use wisparse::sparsity::score::tau_for_keep_ratio;
use wisparse::tensor::Tensor;
use wisparse::util::json::Json;
use wisparse::util::rng::Pcg64;
use wisparse::util::threadpool::num_threads;
use wisparse::util::timer::Bench;

fn main() {
    let bench = Bench::default();
    let mut rng = Pcg64::new(0xBE7C);
    // Distinct (m, n) projection shapes across the three presets.
    let shapes = [
        (128usize, 128usize, "llama attn"),
        (352, 128, "llama up/gate"),
        (128, 352, "llama down"),
        (160, 160, "mistral attn"),
        (432, 160, "mistral up/gate"),
        (96, 96, "qwen attn"),
        (256, 96, "qwen up/gate"),
    ];
    let mut csv = Vec::new();
    println!("== sparse GEMV microbench ==");
    for &(m, n, label) in &shapes {
        let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 0.05, &mut rng));
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        let mut out = vec![0.0f32; m];

        let dense = bench.run(&format!("{label} [{m}x{n}] dense"), || {
            black_box(dense_gemv(&w, black_box(&x), &mut out));
        });
        println!("{}", dense.line());
        csv.push(vec![
            label.into(),
            m.to_string(),
            n.to_string(),
            "0.0".into(),
            f(dense.mean_ns),
            f(1.0),
        ]);
        for sparsity in [0.3, 0.5, 0.7] {
            // Calibrate tau for this sparsity on the score distribution.
            let scores: Vec<f32> = x
                .iter()
                .zip(&ga)
                .map(|(&xv, &g)| xv.abs() * g)
                .collect();
            let tau = tau_for_keep_ratio(&scores, 1.0 - sparsity);
            let r = bench.run(
                &format!("{label} [{m}x{n}] scored s={sparsity}"),
                || {
                    black_box(sparse_gemv_scored(
                        &w,
                        black_box(&x),
                        &ga,
                        tau,
                        &mut out,
                    ));
                },
            );
            println!(
                "{}   speedup {:.2}x (ideal {:.2}x)",
                r.line(),
                dense.mean_ns / r.mean_ns,
                1.0 / (1.0 - sparsity)
            );
            csv.push(vec![
                label.into(),
                m.to_string(),
                n.to_string(),
                format!("{sparsity}"),
                f(r.mean_ns),
                f(dense.mean_ns / r.mean_ns),
            ]);
        }
    }
    // §Perf A/B: scalar accumulation vs 4-column fused accumulation.
    println!("\n== §Perf: scalar vs x4 fused accumulation (50% sparsity) ==");
    for &(m, n, label) in &shapes {
        let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 0.05, &mut rng));
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        let scores: Vec<f32> = x.iter().zip(&ga).map(|(&xv, &g)| xv.abs() * g).collect();
        let tau = tau_for_keep_ratio(&scores, 0.5);
        let mut out = vec![0.0f32; m];
        let a = bench.run(&format!("{label} scalar"), || {
            black_box(sparse_gemv_scored(&w, black_box(&x), &ga, tau, &mut out));
        });
        let b = bench.run(&format!("{label} x4"), || {
            black_box(wisparse::sparse_kernel::gemv::sparse_gemv_scored_x4(
                &w,
                black_box(&x),
                &ga,
                tau,
                &mut out,
            ));
        });
        println!(
            "{label:<18} scalar {:>10}  x4 {:>10}  -> x4 is {:+.1}%",
            wisparse::util::timer::fmt_ns(a.mean_ns),
            wisparse::util::timer::fmt_ns(b.mean_ns),
            (a.mean_ns / b.mean_ns - 1.0) * 100.0
        );
        csv.push(vec![
            format!("{label} x4-ab"),
            m.to_string(),
            n.to_string(),
            "0.5".into(),
            f(b.mean_ns),
            f(a.mean_ns / b.mean_ns),
        ]);
    }

    // §SIMD: scalar reference vs every dispatched fused backend, plus the
    // intra-GEMV row-parallel kernel, at 50% sparsity. Includes a
    // 4096x4096 projection (real-model `lm_head`/`gate` scale) — the shape
    // the tentpole's >=1.3x acceptance criterion is measured on. Results go
    // to BENCH_kernel.json so future PRs can track the perf trajectory.
    println!("\n== §SIMD: scalar reference vs dispatched fused backends (50% sparsity) ==");
    let quick = Bench::quick();
    let threads = num_threads();
    let mut json_shapes: Vec<Json> = Vec::new();
    let simd_shapes = [
        (352usize, 128usize, "llama up/gate"),
        (1024, 1024, "1k proj"),
        (4096, 4096, "4k proj"),
    ];
    for &(m, n, label) in &simd_shapes {
        let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 0.05, &mut rng));
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        let scores: Vec<f32> = x.iter().zip(&ga).map(|(&xv, &g)| xv.abs() * g).collect();
        let tau = tau_for_keep_ratio(&scores, 0.5);
        let kept = count_kept_scored(&x, &ga, tau);
        let col_bytes = (kept * m * std::mem::size_of::<f32>()) as f64;
        let mut out = vec![0.0f32; m];
        let mut entries: Vec<Json> = Vec::new();
        let mut record = |name: &str, mean_ns: f64, scalar_ns: f64| {
            let speedup = scalar_ns / mean_ns;
            let tokens_per_s = 1e9 / mean_ns;
            let gb_per_s = col_bytes / mean_ns; // bytes/ns == GB/s
            println!(
                "{label:<16} {name:<22} {:>10}  {tokens_per_s:>9.0} tok/s  {gb_per_s:>6.1} GB/s  ({speedup:.2}x vs scalar)",
                wisparse::util::timer::fmt_ns(mean_ns)
            );
            entries.push(Json::obj(vec![
                ("backend", Json::Str(name.to_string())),
                ("mean_ns", Json::Num(mean_ns)),
                ("tokens_per_s", Json::Num(tokens_per_s)),
                ("gb_per_s", Json::Num(gb_per_s)),
                ("speedup_vs_scalar", Json::Num(speedup)),
            ]));
        };
        let scalar = quick.run(&format!("{label} scalar-ref"), || {
            black_box(sparse_gemv_scored(&w, black_box(&x), &ga, tau, &mut out));
        });
        record("scalar-ref", scalar.mean_ns, scalar.mean_ns);
        let mut kept_idx: Vec<u32> = Vec::new();
        for backend in simd::available_backends() {
            let r = quick.run(&format!("{label} fused {}", backend.name()), || {
                black_box(sparse_gemv_fused_with(
                    backend,
                    &w,
                    black_box(&x),
                    Some(&ga),
                    tau,
                    &mut out,
                    &mut kept_idx,
                ));
            });
            record(&format!("fused-{}", backend.name()), r.mean_ns, scalar.mean_ns);
        }
        // min_macs = 0 forces the row split so this row measures the
        // parallel kernel on every shape (the production gate would keep
        // the small shapes serial and silently duplicate the fused row).
        let r = quick.run(&format!("{label} fused dispatched+par"), || {
            black_box(sparse_gemv_fused_parallel_with(
                simd::active(),
                &w,
                black_box(&x),
                Some(&ga),
                tau,
                &mut out,
                &mut kept_idx,
                threads,
                0,
            ));
        });
        record(&format!("dispatched-par-t{threads}"), r.mean_ns, scalar.mean_ns);
        json_shapes.push(Json::obj(vec![
            ("label", Json::Str(label.to_string())),
            ("m", Json::Num(m as f64)),
            ("n", Json::Num(n as f64)),
            ("sparsity", Json::Num(0.5)),
            ("kept", Json::Num(kept as f64)),
            ("backends", Json::Arr(entries)),
        ]));
    }
    // §Obs A/B: full synthetic-model forward with the default no-op
    // ObsSink vs the recording BlockObs sink. The no-op column is the one
    // the acceptance criterion pins: it must sit within noise of a build
    // that predates the obs subsystem entirely.
    println!("\n== §Obs: no-op vs recording ObsSink (synthetic forward, 16 tok) ==");
    let obs_cfg = wisparse::model::ModelConfig::preset("nano").expect("nano preset");
    let mut noop_model = wisparse::model::transformer::Model::synthetic(obs_cfg.clone(), 7);
    let mut rec_model = wisparse::model::transformer::Model::synthetic(obs_cfg, 7);
    noop_model.set_obs_sink(std::sync::Arc::new(wisparse::obs::NoopSink));
    rec_model.set_obs_sink(std::sync::Arc::new(wisparse::obs::BlockObs::new(
        rec_model.cfg.n_layers,
    )));
    let obs_tokens: Vec<usize> = (0..16).map(|i| (i * 13) % noop_model.cfg.vocab_size).collect();
    let mut stats = wisparse::model::transformer::ForwardStats::default();
    let noop = quick.run("forward noop-sink", || {
        black_box(noop_model.forward_seq(
            black_box(&obs_tokens),
            &wisparse::sparsity::Dense,
            &mut stats,
            None,
        ));
    });
    let rec = quick.run("forward recording-sink", || {
        black_box(rec_model.forward_seq(
            black_box(&obs_tokens),
            &wisparse::sparsity::Dense,
            &mut stats,
            None,
        ));
    });
    println!("{}", noop.line());
    println!(
        "{}   recording overhead {:+.1}%",
        rec.line(),
        (rec.mean_ns / noop.mean_ns - 1.0) * 100.0
    );
    // §Batch fusion (ISSUE 8 headline): fused vs per-sequence decode tok/s
    // at batch sizes 1/2/4/8. The fused step streams each weight column once
    // per step under the union of the batch's masks; the per-sequence path
    // streams the weights once per *member*, so on a model larger than cache
    // the fused curve must pull ahead (>=1.3x at batch 8 is the acceptance
    // gate, asserted by CI). threads=1 isolates the weight-streaming effect
    // from batch-level parallelism; both paths stay under the kernels'
    // intra-op parallel threshold so the comparison is serial vs serial.
    println!("\n== §Batch fusion: fused vs per-sequence decode scaling ==");
    let bcfg = ModelConfig {
        name: "bench-batch".to_string(),
        vocab_size: 4096,
        d_model: 384,
        n_layers: 6,
        n_heads: 4,
        ffn_dim: 1536,
        max_seq: 96,
        rope_base: 10000.0,
        rmsnorm_eps: 1e-5,
    };
    let bmodel = Arc::new(Model::synthetic(bcfg.clone(), 0xFA5E));
    let blayers: Vec<ScoredLayer> = (0..bcfg.n_layers * 7)
        .map(|_| ScoredLayer { ga: None, tau: 0.5 })
        .collect();
    let bsp: Arc<dyn Sparsifier> = Arc::new(ScoredSparsifier::new("teal", blayers));
    let decode_tokens = 12usize;
    let bprompts = [
        "the quick brown",
        "pack my box",
        "sphinx of black",
        "jackdaws love my",
        "mr jock tv quiz",
        "five boxing wizards",
        "how vexingly quick",
        "waltz bad nymph",
    ];
    // Returns (best-of-2 elapsed seconds, generated tokens, fnv over the
    // final logits bits) so the A/B can assert bit identity alongside tok/s.
    let run = |batch: usize, fused: bool| -> (f64, Vec<Vec<usize>>, Vec<u64>) {
        let mut best = f64::INFINITY;
        let mut gen: Vec<Vec<usize>> = Vec::new();
        let mut bits: Vec<u64> = Vec::new();
        for rep in 0..2 {
            let e = Engine::new(
                Arc::clone(&bmodel),
                Arc::clone(&bsp),
                EngineCfg {
                    threads: 1,
                    fused_batch: fused,
                    ..EngineCfg::default()
                },
            );
            let mut seqs: Vec<_> = (0..batch)
                .map(|i| {
                    e.admit(
                        i as u64,
                        bprompts[i % bprompts.len()],
                        decode_tokens,
                        Sampling::Greedy,
                    )
                })
                .collect();
            for s in seqs.iter_mut() {
                e.prefill(s);
            }
            let t0 = std::time::Instant::now();
            while seqs.iter().any(|s| !s.finished()) {
                e.step_batch(&mut seqs);
            }
            let el = t0.elapsed().as_secs_f64();
            best = best.min(el);
            if rep == 0 {
                gen = seqs.iter().map(|s| s.generated.clone()).collect();
                bits = seqs
                    .iter()
                    .map(|s| {
                        let mut h = 0xcbf29ce484222325u64;
                        for v in e.last_logits(s) {
                            h = (h ^ v.to_bits() as u64).wrapping_mul(0x100000001b3);
                        }
                        h
                    })
                    .collect();
            }
        }
        (best, gen, bits)
    };
    let wbytes = bmodel.weight_bytes_resident() as f64;
    let mut brows: Vec<Json> = Vec::new();
    for &batch in &[1usize, 2, 4, 8] {
        let (fe, fgen, fbits) = run(batch, true);
        let (pe, pgen, pbits) = run(batch, false);
        let toks = (batch * decode_tokens) as f64;
        let (ftok, ptok) = (toks / fe, toks / pe);
        let speedup = ftok / ptok;
        let ident = fgen == pgen && fbits == pbits;
        // Dense-equivalent weight traffic: the fused path walks the weights
        // once per step, the per-sequence path once per live member.
        let f_gb = wbytes * decode_tokens as f64 / fe / 1e9;
        let p_gb = wbytes * (decode_tokens * batch) as f64 / pe / 1e9;
        println!(
            "batch {batch}: fused {ftok:>6.0} tok/s ({f_gb:.2} GB/s dense-equiv)  \
             per-seq {ptok:>6.0} tok/s ({p_gb:.2} GB/s)  speedup {speedup:.2}x  \
             bit_identical {ident}"
        );
        brows.push(Json::obj(vec![
            ("batch", Json::Num(batch as f64)),
            ("fused_tok_s", Json::Num(ftok)),
            ("per_seq_tok_s", Json::Num(ptok)),
            ("speedup", Json::Num(speedup)),
            ("fused_weight_gb_s", Json::Num(f_gb)),
            ("per_seq_weight_gb_s", Json::Num(p_gb)),
            ("bit_identical", Json::Bool(ident)),
        ]));
    }

    // §Shadow sampling (ISSUE 9): decode tok/s with the quality monitor off
    // vs the default 1-in-100 shadow-dense rate. The sampled column pays one
    // extra dense forward per 100 decode steps; the acceptance gate is <2%
    // overhead at rate 0.01. A single long sequence, because the sampling
    // counter is per-sequence: short sequences would never reach step 100.
    println!("\n== §Shadow sampling: decode overhead at rate 0.01 ==");
    let scfg = ModelConfig {
        name: "bench-shadow".to_string(),
        vocab_size: 2048,
        d_model: 256,
        n_layers: 4,
        n_heads: 4,
        ffn_dim: 1024,
        max_seq: 192,
        rope_base: 10000.0,
        rmsnorm_eps: 1e-5,
    };
    let smodel = Arc::new(Model::synthetic(scfg.clone(), 0x5AD0));
    let ssp: Arc<dyn Sparsifier> = Arc::new(ScoredSparsifier::new(
        "teal",
        (0..scfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau: 0.5 })
            .collect(),
    ));
    let shadow_decode = 160usize;
    let srun = |rate: f64| -> (f64, Vec<usize>, u64) {
        let mut best = f64::INFINITY;
        let mut gen = Vec::new();
        let mut samples = 0u64;
        for rep in 0..3 {
            let e = Engine::new(
                Arc::clone(&smodel),
                Arc::clone(&ssp),
                EngineCfg {
                    threads: 1,
                    quality_sample_rate: rate,
                    ..EngineCfg::default()
                },
            );
            let mut s = e.admit(0, "shadow bench", shadow_decode, Sampling::Greedy);
            e.prefill(&mut s);
            let t0 = std::time::Instant::now();
            while !s.finished() {
                e.decode_one(&mut s);
            }
            best = best.min(t0.elapsed().as_secs_f64());
            if rep == 0 {
                gen = s.generated.clone();
                samples = e.quality.as_ref().map_or(0, |q| q.samples());
            }
        }
        (best, gen, samples)
    };
    let (base_s, base_gen, _) = srun(0.0);
    let (samp_s, samp_gen, shadow_samples) = srun(0.01);
    let (base_tok, samp_tok) = (
        shadow_decode as f64 / base_s,
        shadow_decode as f64 / samp_s,
    );
    let shadow_overhead_pct = (base_tok / samp_tok - 1.0) * 100.0;
    let shadow_identical = base_gen == samp_gen;
    println!(
        "rate 0.00 {base_tok:>7.0} tok/s   rate 0.01 {samp_tok:>7.0} tok/s \
         ({shadow_samples} shadow samples)  overhead {shadow_overhead_pct:+.2}%  \
         tokens_identical {shadow_identical}"
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("kernel".to_string())),
        ("simd_active", Json::Str(simd::active().name().to_string())),
        ("threads", Json::Num(threads as f64)),
        ("shapes", Json::Arr(json_shapes)),
        (
            "batch_scaling",
            Json::obj(vec![
                ("model", bcfg.to_json()),
                ("weight_mb", Json::Num(wbytes / 1e6)),
                ("decode_tokens", Json::Num(decode_tokens as f64)),
                ("rows", Json::Arr(brows)),
            ]),
        ),
        (
            "obs_sink",
            Json::obj(vec![
                ("noop_forward_ns", Json::Num(noop.mean_ns)),
                ("recording_forward_ns", Json::Num(rec.mean_ns)),
                (
                    "recording_overhead_pct",
                    Json::Num((rec.mean_ns / noop.mean_ns - 1.0) * 100.0),
                ),
            ]),
        ),
        (
            "shadow_sampling",
            Json::obj(vec![
                ("model", scfg.to_json()),
                ("rate", Json::Num(0.01)),
                ("decode_tokens", Json::Num(shadow_decode as f64)),
                ("samples", Json::Num(shadow_samples as f64)),
                ("baseline_tok_s", Json::Num(base_tok)),
                ("sampled_tok_s", Json::Num(samp_tok)),
                ("overhead_pct", Json::Num(shadow_overhead_pct)),
                ("tokens_identical", Json::Bool(shadow_identical)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_kernel.json", report.to_string_pretty()).expect("BENCH_kernel.json");
    println!("-> BENCH_kernel.json");

    // Scoring overhead: scored with tau=0 (keeps all) vs dense.
    println!("\n== scoring overhead (tau=0: same work + scoring) ==");
    let (m, n) = (352, 128);
    let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 0.05, &mut rng));
    let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let ga = vec![1.0f32; n];
    let mut out = vec![0.0f32; m];
    let d = bench.run("overhead dense", || {
        black_box(dense_gemv(&w, black_box(&x), &mut out));
    });
    let s = bench.run("overhead scored tau=0", || {
        black_box(sparse_gemv_scored(&w, black_box(&x), &ga, 0.0, &mut out));
    });
    let overhead = (s.mean_ns / d.mean_ns - 1.0) * 100.0;
    println!("{}", d.line());
    println!("{}", s.line());
    println!("scoring overhead: {overhead:+.1}% (paper: negligible)");
    write_csv(
        std::path::Path::new("results/bench_kernel.csv"),
        &["shape", "m", "n", "sparsity", "mean_ns", "speedup"],
        &csv,
    )
    .expect("csv");
    println!("-> results/bench_kernel.csv");
}
