//! Kernel microbenchmarks (Fig 4-left's mechanism): dense vs channel-
//! skipping GEMV on every distinct projection shape of the micro models,
//! across sparsity levels. Verifies the core claim that compute scales
//! ~linearly with kept channels and that scoring overhead is negligible.
//!
//!     cargo bench --bench kernel

use std::hint::black_box;
use wisparse::report::csv::{f, write_csv};
use wisparse::sparse_kernel::{dense_gemv, sparse_gemv_scored, ColMajorMatrix};
use wisparse::sparsity::score::tau_for_keep_ratio;
use wisparse::tensor::Tensor;
use wisparse::util::rng::Pcg64;
use wisparse::util::timer::Bench;

fn main() {
    let bench = Bench::default();
    let mut rng = Pcg64::new(0xBE7C);
    // Distinct (m, n) projection shapes across the three presets.
    let shapes = [
        (128usize, 128usize, "llama attn"),
        (352, 128, "llama up/gate"),
        (128, 352, "llama down"),
        (160, 160, "mistral attn"),
        (432, 160, "mistral up/gate"),
        (96, 96, "qwen attn"),
        (256, 96, "qwen up/gate"),
    ];
    let mut csv = Vec::new();
    println!("== sparse GEMV microbench ==");
    for &(m, n, label) in &shapes {
        let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 0.05, &mut rng));
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        let mut out = vec![0.0f32; m];

        let dense = bench.run(&format!("{label} [{m}x{n}] dense"), || {
            black_box(dense_gemv(&w, black_box(&x), &mut out));
        });
        println!("{}", dense.line());
        csv.push(vec![
            label.into(),
            m.to_string(),
            n.to_string(),
            "0.0".into(),
            f(dense.mean_ns),
            f(1.0),
        ]);
        for sparsity in [0.3, 0.5, 0.7] {
            // Calibrate tau for this sparsity on the score distribution.
            let scores: Vec<f32> = x
                .iter()
                .zip(&ga)
                .map(|(&xv, &g)| xv.abs() * g)
                .collect();
            let tau = tau_for_keep_ratio(&scores, 1.0 - sparsity);
            let r = bench.run(
                &format!("{label} [{m}x{n}] scored s={sparsity}"),
                || {
                    black_box(sparse_gemv_scored(
                        &w,
                        black_box(&x),
                        &ga,
                        tau,
                        &mut out,
                    ));
                },
            );
            println!(
                "{}   speedup {:.2}x (ideal {:.2}x)",
                r.line(),
                dense.mean_ns / r.mean_ns,
                1.0 / (1.0 - sparsity)
            );
            csv.push(vec![
                label.into(),
                m.to_string(),
                n.to_string(),
                format!("{sparsity}"),
                f(r.mean_ns),
                f(dense.mean_ns / r.mean_ns),
            ]);
        }
    }
    // §Perf A/B: scalar accumulation vs 4-column fused accumulation.
    println!("\n== §Perf: scalar vs x4 fused accumulation (50% sparsity) ==");
    for &(m, n, label) in &shapes {
        let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 0.05, &mut rng));
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        let scores: Vec<f32> = x.iter().zip(&ga).map(|(&xv, &g)| xv.abs() * g).collect();
        let tau = tau_for_keep_ratio(&scores, 0.5);
        let mut out = vec![0.0f32; m];
        let a = bench.run(&format!("{label} scalar"), || {
            black_box(sparse_gemv_scored(&w, black_box(&x), &ga, tau, &mut out));
        });
        let b = bench.run(&format!("{label} x4"), || {
            black_box(wisparse::sparse_kernel::gemv::sparse_gemv_scored_x4(
                &w,
                black_box(&x),
                &ga,
                tau,
                &mut out,
            ));
        });
        println!(
            "{label:<18} scalar {:>10}  x4 {:>10}  -> x4 is {:+.1}%",
            wisparse::util::timer::fmt_ns(a.mean_ns),
            wisparse::util::timer::fmt_ns(b.mean_ns),
            (a.mean_ns / b.mean_ns - 1.0) * 100.0
        );
        csv.push(vec![
            format!("{label} x4-ab"),
            m.to_string(),
            n.to_string(),
            "0.5".into(),
            f(b.mean_ns),
            f(a.mean_ns / b.mean_ns),
        ]);
    }

    // Scoring overhead: scored with tau=0 (keeps all) vs dense.
    println!("\n== scoring overhead (tau=0: same work + scoring) ==");
    let (m, n) = (352, 128);
    let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 0.05, &mut rng));
    let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let ga = vec![1.0f32; n];
    let mut out = vec![0.0f32; m];
    let d = bench.run("overhead dense", || {
        black_box(dense_gemv(&w, black_box(&x), &mut out));
    });
    let s = bench.run("overhead scored tau=0", || {
        black_box(sparse_gemv_scored(&w, black_box(&x), &ga, 0.0, &mut out));
    });
    let overhead = (s.mean_ns / d.mean_ns - 1.0) * 100.0;
    println!("{}", d.line());
    println!("{}", s.line());
    println!("scoring overhead: {overhead:+.1}% (paper: negligible)");
    write_csv(
        std::path::Path::new("results/bench_kernel.csv"),
        &["shape", "m", "n", "sparsity", "mean_ns", "speedup"],
        &csv,
    )
    .expect("csv");
    println!("-> results/bench_kernel.csv");
}
