"""Layer-2: the micro-Llama forward pass in JAX.

Numerics mirror `rust/src/model/transformer.rs` exactly (RMSNorm, half-split
RoPE, causal MHA with 1/sqrt(hd) scaling, SwiGLU) so the PJRT
cross-validation in `rust/src/runtime/validate.rs` can assert agreement.

Two variants share the code path:
  * dense: every projection is a plain matmul;
  * wisparse: every *block* projection goes through the Layer-1 Pallas
    kernel with per-layer (ga, tau) parameters (Eq. 4-5).
"""

import jax
import jax.numpy as jnp

from compile.kernels.wisparse_matmul import wisparse_matmul
from compile.presets import config_dict

LAYER_KINDS = ("q_proj", "k_proj", "v_proj", "o_proj", "gate_proj", "up_proj", "down_proj")

# Weight-tensor naming (must match rust/src/model/weights.rs conventions).
_ATTN_SHORT = {"q_proj": "q", "k_proj": "k", "v_proj": "v", "o_proj": "o"}
_MLP_SHORT = {"gate_proj": "gate", "up_proj": "up", "down_proj": "down"}


def weight_name(block, kind):
    if kind in _ATTN_SHORT:
        return f"blocks.{block}.attn.w{_ATTN_SHORT[kind]}.weight"
    return f"blocks.{block}.mlp.w_{_MLP_SHORT[kind]}.weight"


def param_order(cfg):
    """Deterministic parameter order used by the trainer, the AOT export
    and the Rust manifest loader."""
    names = ["embed.weight"]
    for b in range(cfg["n_layers"]):
        names.append(f"blocks.{b}.attn_norm.weight")
        for kind in ("q_proj", "k_proj", "v_proj", "o_proj"):
            names.append(weight_name(b, kind))
        names.append(f"blocks.{b}.mlp_norm.weight")
        for kind in ("gate_proj", "up_proj", "down_proj"):
            names.append(weight_name(b, kind))
    names.append("final_norm.weight")
    names.append("lm_head.weight")
    return names


def param_shape(cfg, name):
    d, f, v = cfg["d_model"], cfg["ffn_dim"], cfg["vocab_size"]
    if name in ("embed.weight", "lm_head.weight"):
        return (v, d)
    if name.endswith("norm.weight"):
        return (d,)
    kind = name.split(".")[-2]
    if kind in ("wq", "wk", "wv", "wo"):
        return (d, d)
    if kind in ("w_gate", "w_up"):
        return (f, d)
    if kind == "w_down":
        return (d, f)
    raise ValueError(f"unknown param {name}")


def init_params(cfg, key):
    """Gaussian init matching Model::synthetic's scales."""
    params = {}
    d = cfg["d_model"]
    std = 0.7 / (d ** 0.5)
    for name in param_order(cfg):
        shape = param_shape(cfg, name)
        key, sub = jax.random.split(key)
        if name.endswith("norm.weight"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name in ("embed.weight", "lm_head.weight"):
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def rmsnorm(x, w, eps):
    ms = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope(x, positions, base):
    """Half-split rotary embedding on [T, H, hd] (matches rope_inplace)."""
    t, h, hd = x.shape
    half = hd // 2
    i = jnp.arange(half, dtype=jnp.float32)
    freq = 1.0 / (base ** (2.0 * i / hd))  # [half]
    angle = positions[:, None].astype(jnp.float32) * freq[None, :]  # [T, half]
    sin = jnp.sin(angle)[:, None, :]
    cos = jnp.cos(angle)[:, None, :]
    a, b = x[..., :half], x[..., half:]
    return jnp.concatenate([a * cos - b * sin, a * sin + b * cos], axis=-1)


def _project(x, w, sparse_params, block, kind, use_pallas):
    """One linear projection, dense or through the L1 kernel."""
    if sparse_params is None:
        return x @ w.T
    ga = sparse_params[f"sparse.{block}.{kind}.ga"]
    tau = sparse_params[f"sparse.{block}.{kind}.tau"][0]
    if use_pallas:
        return wisparse_matmul(x, w, ga, tau)
    # jnp fallback (identical math; used inside jitted training evals).
    keep = (jnp.abs(x) * ga[None, :]) >= tau
    return jnp.where(keep, x, 0.0) @ w.T


def forward(params, tokens, cfg, sparse_params=None, use_pallas=True):
    """Full-sequence causal forward. tokens: int32 [T] -> logits [T, vocab].

    `sparse_params`: dict of `sparse.<block>.<kind>.{ga,tau}` arrays; None
    runs dense. Masking applies to all positions (the calibration/eval
    convention; the serving-time prefill policy lives in the Rust engine).
    """
    t = tokens.shape[0]
    d = cfg["d_model"]
    h = cfg["n_heads"]
    hd = d // h
    eps = cfg["rmsnorm_eps"]
    positions = jnp.arange(t)
    x = params["embed.weight"][tokens]  # [T, d]
    causal = jnp.tril(jnp.ones((t, t), jnp.float32))
    neg = jnp.asarray(-1e30, jnp.float32)
    for b in range(cfg["n_layers"]):
        # --- attention ---
        xn = rmsnorm(x, params[f"blocks.{b}.attn_norm.weight"], eps)
        q = _project(xn, params[weight_name(b, "q_proj")], sparse_params, b, "q_proj", use_pallas)
        k = _project(xn, params[weight_name(b, "k_proj")], sparse_params, b, "k_proj", use_pallas)
        v = _project(xn, params[weight_name(b, "v_proj")], sparse_params, b, "v_proj", use_pallas)
        q = rope(q.reshape(t, h, hd), positions, cfg["rope_base"])
        k = rope(k.reshape(t, h, hd), positions, cfg["rope_base"])
        v = v.reshape(t, h, hd)
        scores = jnp.einsum("qhd,khd->hqk", q, k) / (hd ** 0.5)
        scores = jnp.where(causal[None, :, :] > 0, scores, neg)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("hqk,khd->qhd", probs, v).reshape(t, d)
        o = _project(attn, params[weight_name(b, "o_proj")], sparse_params, b, "o_proj", use_pallas)
        x = x + o
        # --- SwiGLU MLP ---
        xn = rmsnorm(x, params[f"blocks.{b}.mlp_norm.weight"], eps)
        g = _project(xn, params[weight_name(b, "gate_proj")], sparse_params, b, "gate_proj", use_pallas)
        u = _project(xn, params[weight_name(b, "up_proj")], sparse_params, b, "up_proj", use_pallas)
        hidden = jax.nn.silu(g) * u
        dn = _project(hidden, params[weight_name(b, "down_proj")], sparse_params, b, "down_proj", use_pallas)
        x = x + dn
    x = rmsnorm(x, params["final_norm.weight"], eps)
    return x @ params["lm_head.weight"].T


def forward_batch(params, tokens, cfg):
    """vmapped dense forward for training. tokens: [B, T] -> [B, T, vocab]."""
    return jax.vmap(lambda seq: forward(params, seq, cfg, None, use_pallas=False))(tokens)


def make_config(name):
    return config_dict(name)
