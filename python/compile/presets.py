"""Model presets — MUST mirror `rust/src/model/config.rs::ModelConfig::preset`.

The Rust side owns the definition; `python/tests/test_presets.py` parses the
Rust source to assert the two tables stay in sync.
"""

PRESETS = {
    # name: (d_model, n_layers, n_heads, ffn_dim)
    "llama-micro": (128, 8, 4, 352),
    "mistral-micro": (160, 6, 4, 432),
    "qwen-micro": (96, 10, 4, 256),
    "nano": (32, 2, 2, 64),
}

VOCAB_SIZE = 256
MAX_SEQ = 256
ROPE_BASE = 10000.0
RMSNORM_EPS = 1e-5


def config_dict(name):
    d, layers, heads, ffn = PRESETS[name]
    return {
        "name": name,
        "vocab_size": VOCAB_SIZE,
        "d_model": d,
        "n_layers": layers,
        "n_heads": heads,
        "ffn_dim": ffn,
        "max_seq": MAX_SEQ,
        "rope_base": ROPE_BASE,
        "rmsnorm_eps": RMSNORM_EPS,
    }
