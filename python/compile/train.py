"""Build-time trainer: fits the micro models on the synthetic corpus written
by `wisparse gen-data`, then exports config.json + weights.bin (WSPW0001)
and the training loss curve.

Usage:
    python -m compile.train --models llama-micro,mistral-micro,qwen-micro \
        --corpus ../artifacts/data/corpus.txt --out ../artifacts/models \
        --steps 600
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import forward_batch, init_params, make_config, param_order
from compile.weights_io import save_weights


def load_corpus(path, max_bytes=None):
    with open(path, "rb") as f:
        data = f.read()
    if max_bytes:
        data = data[:max_bytes]
    return np.frombuffer(data, dtype=np.uint8).astype(np.int32)


def sample_batch(corpus, batch, seq_len, rng):
    starts = rng.integers(0, len(corpus) - seq_len - 1, size=batch)
    x = np.stack([corpus[s : s + seq_len] for s in starts])
    y = np.stack([corpus[s + 1 : s + seq_len + 1] for s in starts])
    return jnp.asarray(x), jnp.asarray(y)


def loss_fn(params, x, y, cfg):
    logits = forward_batch(params, x, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1).squeeze(-1)
    return jnp.mean(nll)


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vh_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))

    def upd(p, m_, v_):
        mh = m_ * mh_scale
        vh = v_ * vh_scale
        return p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(step, total, base=3e-3, warmup=40):
    warm = jnp.minimum(step / warmup, 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    return base * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def train_model(name, corpus, out_dir, steps, batch, seq_len, seed, log_every=50):
    cfg = make_config(name)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step_fn(params, opt, x, y, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, cfg)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    curve = []
    t0 = time.time()
    for step in range(steps):
        x, y = sample_batch(corpus, batch, seq_len, rng)
        lr = cosine_lr(jnp.asarray(step, jnp.float32), steps)
        params, opt, loss = step_fn(params, opt, x, y, lr)
        if step % log_every == 0 or step == steps - 1:
            loss_v = float(loss)
            curve.append((step, loss_v))
            print(f"[{name}] step {step:4d} loss {loss_v:.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    model_dir = os.path.join(out_dir, name)
    os.makedirs(model_dir, exist_ok=True)
    with open(os.path.join(model_dir, "config.json"), "w") as f:
        json.dump(cfg, f, indent=2)
    tensors = {k: np.asarray(v) for k, v in params.items()}
    # Order check: every expected parameter present, no extras.
    assert set(tensors) == set(param_order(cfg))
    save_weights(os.path.join(model_dir, "weights.bin"), tensors)
    with open(os.path.join(model_dir, "loss_curve.csv"), "w") as f:
        f.write("step,loss\n")
        for s, l in curve:
            f.write(f"{s},{l:.6f}\n")
    print(f"[{name}] saved to {model_dir} (final loss {curve[-1][1]:.4f})")
    return curve[-1][1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="llama-micro,mistral-micro,qwen-micro")
    ap.add_argument("--corpus", default="../artifacts/data/corpus.txt")
    ap.add_argument("--out", default="../artifacts/models")
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    corpus = load_corpus(args.corpus)
    print(f"corpus: {len(corpus)} bytes from {args.corpus}")
    for i, name in enumerate(args.models.split(",")):
        train_model(
            name.strip(), corpus, args.out, args.steps, args.batch,
            args.seq_len, seed=args.seed + i,
        )


if __name__ == "__main__":
    main()
