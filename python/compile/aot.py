"""AOT export: lower the JAX model (dense + wisparse variants) to HLO text
for the Rust PJRT runtime, plus the parameter manifest the runtime feeds
literals by.

HLO *text* is the interchange format — jax >= 0.5 serializes protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --models llama-micro,... --models-dir ../artifacts/models \
        --seq-len 64
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import LAYER_KINDS, forward, make_config, param_order, param_shape


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sparse_param_order(cfg):
    """`sparse.<block>.<kind>.{ga,tau}` in deterministic order."""
    names = []
    for b in range(cfg["n_layers"]):
        for kind in LAYER_KINDS:
            names.append(f"sparse.{b}.{kind}.ga")
            names.append(f"sparse.{b}.{kind}.tau")
    return names


def sparse_param_shape(cfg, name):
    if name.endswith(".tau"):
        return (1,)
    kind = name.split(".")[2]
    return (cfg["ffn_dim"],) if kind == "down_proj" else (cfg["d_model"],)


def export_variant(name, cfg, variant, seq_len, out_dir):
    weight_names = param_order(cfg)
    sparse_names = sparse_param_order(cfg) if variant == "wisparse" else []

    def fn(tokens, *flat):
        params = dict(zip(weight_names, flat[: len(weight_names)]))
        sparse = (
            dict(zip(sparse_names, flat[len(weight_names):])) if sparse_names else None
        )
        # use_pallas=True: the L1 kernel lowers (interpret mode) into the
        # same HLO module, so the export exercises the full 3-layer stack.
        return (forward(params, tokens, cfg, sparse, use_pallas=True),)

    tok_spec = jax.ShapeDtypeStruct((seq_len,), jnp.int32)
    specs = [tok_spec]
    for n in weight_names:
        specs.append(jax.ShapeDtypeStruct(param_shape(cfg, n), jnp.float32))
    for n in sparse_names:
        specs.append(jax.ShapeDtypeStruct(sparse_param_shape(cfg, n), jnp.float32))

    lowered = jax.jit(fn).lower(*specs)
    hlo = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{variant}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)

    manifest = {
        "model": name,
        "variant": variant,
        "seq_len": seq_len,
        "vocab_size": cfg["vocab_size"],
        "params": [
            {"name": n, "shape": list(param_shape(cfg, n))} for n in weight_names
        ]
        + [
            {"name": n, "shape": list(sparse_param_shape(cfg, n))}
            for n in sparse_names
        ],
    }
    with open(os.path.join(out_dir, f"{variant}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[{name}] exported {variant}: {len(hlo)} chars of HLO", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="llama-micro,mistral-micro,qwen-micro")
    ap.add_argument("--models-dir", default="../artifacts/models")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--variants", default="dense,wisparse")
    args = ap.parse_args()

    for name in args.models.split(","):
        name = name.strip()
        cfg = make_config(name)
        out_dir = os.path.join(args.models_dir, name)
        os.makedirs(out_dir, exist_ok=True)
        for variant in args.variants.split(","):
            export_variant(name, cfg, variant.strip(), args.seq_len, out_dir)


if __name__ == "__main__":
    main()
