"""Layer-1 Pallas kernel: weight-aware scored sparse matmul.

TPU adaptation of TEAL's Triton gather-GEMV (DESIGN.md §6): scoring and
masking are a VPU elementwise pass over the activation tile resident in
VMEM; the contraction feeds the MXU with dense tiles (TPU has no lane
compaction), so sparsity is realized as masked values — the *scheduling*
win on TPU comes from BlockSpec tiling that keeps each (x-tile, w-tile)
pair in VMEM, while the arithmetic win is measured on the Rust engine.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO and runs (and AOT-exports)
on CPU. The BlockSpec structure is still the real TPU schedule; DESIGN.md
§7 estimates VMEM/MXU numbers from it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, ga_ref, tau_ref, o_ref):
    """One (B-tile, M-tile) grid cell.

    x_ref:  [bB, N]  activation tile (VMEM)
    w_ref:  [bM, N]  weight tile (VMEM)
    ga_ref: [1, N]   precomputed g^alpha
    tau_ref:[1, 1]   threshold
    o_ref:  [bB, bM] output tile
    """
    x = x_ref[...]
    ga = ga_ref[...]
    tau = tau_ref[0, 0]
    # VPU pass: weight-aware score + mask (Eq. 4-5). One abs, one mul, one
    # compare per element — the paper's "negligible overhead".
    keep = (jnp.abs(x) * ga) >= tau
    masked = jnp.where(keep, x, jnp.zeros_like(x))
    # MXU pass: dense tile contraction on the masked activations.
    o_ref[...] = jax.lax.dot_general(
        masked,
        w_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _pick_tile(dim, target):
    """Largest divisor of `dim` that is <= target (keeps BlockSpec exact)."""
    t = min(dim, target)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("block_b", "block_m"))
def wisparse_matmul_pallas(x, w, ga, tau, *, block_b=8, block_m=128):
    """Pallas-tiled y = (x ⊙ m) W^T with m from the weight-aware score.

    Args:
      x:  [B, N] f32 activations.
      w:  [M, N] f32 weights.
      ga: [N] f32 precomputed g^alpha.
      tau: scalar f32 threshold.
      block_b / block_m: tile shape targets (clamped to divisors).

    Returns: [B, M] f32.
    """
    b_dim, n = x.shape
    m_dim, n2 = w.shape
    assert n == n2, f"x cols {n} != w cols {n2}"
    assert ga.shape == (n,), ga.shape
    bb = _pick_tile(b_dim, block_b)
    bm = _pick_tile(m_dim, block_m)
    ga2 = ga.reshape(1, n)
    tau2 = jnp.asarray(tau, jnp.float32).reshape(1, 1)
    grid = (b_dim // bb, m_dim // bm)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b_dim, m_dim), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, ga2, tau2)


def wisparse_matmul(x, w, ga, tau):
    """Public entry: default tile sizes."""
    return wisparse_matmul_pallas(x, w, ga, tau)


def vmem_footprint_bytes(n, block_b=8, block_m=128, dtype_bytes=4):
    """Estimated VMEM working set of one grid cell (double-buffered):
    x tile + w tile + ga + out tile, x2 for pipelining. Used by DESIGN.md §7
    to check tiles fit the ~16 MiB VMEM budget of a TPU core.
    """
    x_tile = block_b * n * dtype_bytes
    w_tile = block_m * n * dtype_bytes
    ga_tile = n * dtype_bytes
    out_tile = block_b * block_m * dtype_bytes
    return 2 * (x_tile + w_tile) + ga_tile + out_tile
