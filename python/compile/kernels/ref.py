"""Pure-jnp oracle for the WiSparse scored sparse matmul.

This is the correctness contract for the Pallas kernel (Eq. 2-5 of the
paper): mask channels whose weight-aware score `|x_i| * ga_i` falls below
`tau`, then project with the original weights.
"""

import jax.numpy as jnp


def ref_scores(x, ga):
    """Weight-aware importance scores s = |x| * ga, ga = g^alpha (Eq. 4)."""
    return jnp.abs(x) * ga


def ref_mask(x, ga, tau):
    """Binary keep-mask m_i = 1[s_i >= tau] (Eq. 5)."""
    return (ref_scores(x, ga) >= tau).astype(x.dtype)


def ref_wisparse_matmul(x, w, ga, tau):
    """y = (x ⊙ m) W^T.

    Args:
      x:  [B, N] activations.
      w:  [M, N] weights (output-major, PyTorch/JAX linear convention).
      ga: [N] precomputed g^alpha (>= 0).
      tau: scalar threshold.

    Returns:
      [B, M] projections.
    """
    masked = x * ref_mask(x, ga, tau)
    return masked @ w.T
