"""Layer-1 Pallas kernels (build-time only; never imported at runtime)."""
