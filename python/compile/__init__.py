"""Build-time Python: JAX model (L2), Pallas kernels (L1), trainer, AOT.

Nothing here runs on the request path — `make artifacts` invokes this
package once, and the Rust coordinator serves from the exported artifacts.
"""
