"""WSPW0001 binary weight format — writer/reader mirroring
`rust/src/model/weights.rs`. Tensors are sorted by name (the Rust side uses
a BTreeMap, so saves are name-ordered; we match for byte-identical
round-trips)."""

import struct

import numpy as np

MAGIC = b"WSPW0001"


def save_weights(path, tensors):
    """tensors: dict name -> np.ndarray (float32, 1-3 dims)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
            assert 1 <= arr.ndim <= 3, f"{name}: ndim {arr.ndim}"
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
            f.write(arr.tobytes())


def load_weights(path):
    """Returns dict name -> np.ndarray(float32)."""
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:8] == MAGIC, "bad magic"
    pos = 8
    (count,) = struct.unpack_from("<I", buf, pos)
    pos += 4
    out = {}
    for _ in range(count):
        (name_len,) = struct.unpack_from("<I", buf, pos)
        pos += 4
        name = buf[pos : pos + name_len].decode("utf-8")
        pos += name_len
        (ndim,) = struct.unpack_from("<I", buf, pos)
        pos += 4
        shape = struct.unpack_from(f"<{ndim}I", buf, pos)
        pos += 4 * ndim
        numel = int(np.prod(shape))
        arr = np.frombuffer(buf, dtype="<f4", count=numel, offset=pos).reshape(shape)
        pos += 4 * numel
        out[name] = arr.copy()
    assert pos == len(buf), f"trailing bytes: {len(buf) - pos}"
    return out
