"""Preset sync: the Python preset table must mirror the Rust source of
truth in rust/src/model/config.rs."""

import os
import re

from compile.presets import PRESETS

RUST_CONFIG = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "src", "model", "config.rs"
)


def rust_presets():
    with open(RUST_CONFIG) as f:
        src = f.read()
    # Lines like: "llama-micro" => (128, 8, 4, 352),
    pat = re.compile(r'"([a-z-]+)"\s*=>\s*\((\d+),\s*(\d+),\s*(\d+),\s*(\d+)\)')
    found = {}
    for name, d, layers, heads, ffn in pat.findall(src):
        found[name] = (int(d), int(layers), int(heads), int(ffn))
    return found


def test_presets_match_rust():
    rust = rust_presets()
    assert rust, "failed to parse rust presets"
    assert rust == PRESETS, f"preset tables diverged:\nrust={rust}\npython={PRESETS}"


def test_head_dims_divide():
    for name, (d, _, heads, _) in PRESETS.items():
        assert d % heads == 0, name
