"""L2 model tests: shapes, causality, dense/sparse consistency."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import sparse_param_order, sparse_param_shape
from compile.model import (
    LAYER_KINDS,
    forward,
    init_params,
    make_config,
    param_order,
    param_shape,
)


CFG = make_config("nano")
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def toks(xs):
    return jnp.asarray(xs, jnp.int32)


def zero_tau_sparse(cfg):
    sp = {}
    for name in sparse_param_order(cfg):
        shape = sparse_param_shape(cfg, name)
        sp[name] = jnp.zeros(shape) if name.endswith(".tau") else jnp.ones(shape)
    return sp


class TestForward:
    def test_shapes(self):
        logits = forward(PARAMS, toks([1, 2, 3]), CFG)
        assert logits.shape == (3, CFG["vocab_size"])
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_causality(self):
        a = forward(PARAMS, toks([1, 2, 3, 4]), CFG)
        b = forward(PARAMS, toks([1, 2, 3, 200]), CFG)
        np.testing.assert_allclose(a[:3], b[:3], atol=1e-5)
        assert float(jnp.abs(a[3] - b[3]).max()) > 1e-6

    def test_context_matters(self):
        a = forward(PARAMS, toks([1, 2, 3]), CFG)
        b = forward(PARAMS, toks([9, 2, 3]), CFG)
        assert float(jnp.abs(a[2] - b[2]).max()) > 1e-6

    def test_sparse_zero_tau_equals_dense(self):
        dense = forward(PARAMS, toks([5, 6, 7]), CFG, None)
        sparse = forward(PARAMS, toks([5, 6, 7]), CFG, zero_tau_sparse(CFG))
        np.testing.assert_allclose(dense, sparse, atol=1e-4)

    def test_sparse_pallas_equals_jnp_fallback(self):
        sp = zero_tau_sparse(CFG)
        # Nonzero taus so masking actually happens.
        for name in list(sp):
            if name.endswith(".tau"):
                sp[name] = jnp.asarray([0.2])
        a = forward(PARAMS, toks([3, 1, 4]), CFG, sp, use_pallas=True)
        b = forward(PARAMS, toks([3, 1, 4]), CFG, sp, use_pallas=False)
        np.testing.assert_allclose(a, b, atol=1e-4)

    def test_sparse_changes_output(self):
        sp = zero_tau_sparse(CFG)
        for name in list(sp):
            if name.endswith(".tau"):
                sp[name] = jnp.asarray([0.5])
        dense = forward(PARAMS, toks([5, 6, 7]), CFG, None)
        sparse = forward(PARAMS, toks([5, 6, 7]), CFG, sp)
        assert float(jnp.abs(dense - sparse).max()) > 1e-6


class TestParams:
    def test_param_order_complete(self):
        names = param_order(CFG)
        assert names[0] == "embed.weight"
        assert names[-1] == "lm_head.weight"
        assert len(names) == 2 + CFG["n_layers"] * 9 + 1
        assert len(set(names)) == len(names)

    def test_param_shapes(self):
        for n in param_order(CFG):
            assert PARAMS[n].shape == param_shape(CFG, n), n

    def test_sparse_param_order(self):
        names = sparse_param_order(CFG)
        assert len(names) == CFG["n_layers"] * len(LAYER_KINDS) * 2
        assert "sparse.0.down_proj.ga" in names
        assert sparse_param_shape(CFG, "sparse.0.down_proj.ga") == (CFG["ffn_dim"],)
        assert sparse_param_shape(CFG, "sparse.0.q_proj.tau") == (1,)
