"""L1 correctness: the Pallas kernel against the pure-jnp oracle.

This is THE core correctness signal for Layer 1 — hypothesis sweeps shapes,
tile sizes, thresholds and degenerate inputs.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ref_mask, ref_wisparse_matmul
from compile.kernels.wisparse_matmul import (
    vmem_footprint_bytes,
    wisparse_matmul,
    wisparse_matmul_pallas,
)


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), jnp.float32)


def assert_close(a, b, atol=1e-5, rtol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


class TestBasics:
    def test_zero_tau_is_dense(self):
        x, w = rand((4, 16), 0), rand((8, 16), 1)
        ga = jnp.ones(16)
        got = wisparse_matmul(x, w, ga, 0.0)
        assert_close(got, x @ w.T, atol=1e-4)

    def test_inf_tau_is_zero(self):
        x, w = rand((4, 16), 2), rand((8, 16), 3)
        ga = jnp.ones(16)
        got = wisparse_matmul(x, w, ga, jnp.inf)
        assert_close(got, jnp.zeros((4, 8)))

    def test_matches_ref_midrange(self):
        x, w = rand((8, 32), 4), rand((24, 32), 5)
        ga = jnp.abs(rand((32,), 6)) + 0.05
        for tau in (0.1, 0.5, 1.5):
            assert_close(
                wisparse_matmul(x, w, ga, tau),
                ref_wisparse_matmul(x, w, ga, tau),
                atol=1e-4,
            )

    def test_weight_aware_rescues_small_activations(self):
        # The Fig-2 phenomenon: tiny activation, huge weight norm.
        x = jnp.asarray([[0.05, 1.0]], jnp.float32)
        w = jnp.asarray([[10.0, 0.1]], jnp.float32)
        ga_act_only = jnp.ones(2)
        ga_weighted = jnp.asarray([10.0, 0.1])
        tau = 0.3
        # Activation-only mask drops channel 0 (score 0.05 < 0.3).
        m0 = ref_mask(x, ga_act_only, tau)
        assert m0[0, 0] == 0.0 and m0[0, 1] == 1.0
        # Weight-aware mask keeps it (score 0.5 >= 0.3).
        m1 = ref_mask(x, ga_weighted, tau)
        assert m1[0, 0] == 1.0

    def test_tile_shapes_do_not_change_result(self):
        x, w = rand((12, 24), 7), rand((36, 24), 8)
        ga = jnp.abs(rand((24,), 9)) + 0.1
        ref = ref_wisparse_matmul(x, w, ga, 0.4)
        for bb, bm in [(1, 1), (3, 9), (4, 36), (12, 12)]:
            got = wisparse_matmul_pallas(x, w, ga, 0.4, block_b=bb, block_m=bm)
            assert_close(got, ref, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 9),
    m=st.integers(1, 40),
    n=st.integers(1, 48),
    tau=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**20),
)
def test_kernel_matches_ref_hypothesis(b, m, n, tau, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    ga = jnp.asarray(np.abs(rng.normal(size=n)) + 1e-3, jnp.float32)
    got = wisparse_matmul(x, w, ga, tau)
    want = ref_wisparse_matmul(x, w, ga, tau)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_mask_sparsity_monotone_in_tau(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(6, 32)), jnp.float32)
    ga = jnp.asarray(np.abs(rng.normal(size=32)) + 1e-3, jnp.float32)
    kept = [float(ref_mask(x, ga, t).sum()) for t in (0.0, 0.3, 0.8, 2.0)]
    assert kept == sorted(kept, reverse=True)
    assert kept[0] == 6 * 32  # tau=0 keeps everything


class TestVmemEstimate:
    def test_default_tiles_fit_vmem(self):
        # Largest layer width across presets is ffn 432.
        assert vmem_footprint_bytes(432) < 16 * 1024 * 1024

    def test_footprint_grows_with_tiles(self):
        assert vmem_footprint_bytes(256, block_m=256) > vmem_footprint_bytes(
            256, block_m=64
        )


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_output_dtype(dtype):
    x, w = rand((2, 8), 10), rand((4, 8), 11)
    out = wisparse_matmul(x.astype(dtype), w.astype(dtype), jnp.ones(8), 0.1)
    assert out.dtype == jnp.float32
