"""AOT export smoke: HLO text parses as HLO-ish, manifest is consistent,
and the exported function is numerically identical to the eager forward."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import export_variant, sparse_param_order, sparse_param_shape
from compile.model import forward, init_params, make_config, param_order


CFG = make_config("nano")
SEQ = 8


def test_export_dense(tmp_path):
    export_variant("nano", CFG, "dense", SEQ, str(tmp_path))
    hlo = (tmp_path / "dense.hlo.txt").read_text()
    assert "HloModule" in hlo
    assert "f32[8,256]" in hlo  # logits shape appears in the module
    manifest = json.loads((tmp_path / "dense.manifest.json").read_text())
    assert manifest["seq_len"] == SEQ
    assert manifest["variant"] == "dense"
    names = [p["name"] for p in manifest["params"]]
    assert names == param_order(CFG)


def test_export_wisparse_manifest(tmp_path):
    export_variant("nano", CFG, "wisparse", SEQ, str(tmp_path))
    manifest = json.loads((tmp_path / "wisparse.manifest.json").read_text())
    names = [p["name"] for p in manifest["params"]]
    assert names == param_order(CFG) + sparse_param_order(CFG)
    for p in manifest["params"]:
        if p["name"].startswith("sparse.") and p["name"].endswith(".tau"):
            assert p["shape"] == [1]


def test_sparse_shapes_table():
    for n in sparse_param_order(CFG):
        s = sparse_param_shape(CFG, n)
        assert s in [(1,), (CFG["d_model"],), (CFG["ffn_dim"],)]
