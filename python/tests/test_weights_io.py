"""WSPW0001 format round-trip + cross-language conventions."""

import numpy as np
import pytest

from compile.weights_io import load_weights, save_weights


def test_roundtrip(tmp_path):
    tensors = {
        "a.weight": np.random.default_rng(0).normal(size=(3, 4)).astype(np.float32),
        "b": np.arange(7, dtype=np.float32),
        "c3": np.zeros((2, 3, 4), np.float32),
    }
    path = tmp_path / "w.bin"
    save_weights(path, tensors)
    back = load_weights(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


def test_name_sorted_on_disk(tmp_path):
    path = tmp_path / "w.bin"
    save_weights(path, {"zz": np.ones(1, np.float32), "aa": np.ones(1, np.float32)})
    raw = path.read_bytes()
    assert raw.index(b"aa") < raw.index(b"zz")


def test_bad_magic_rejected(tmp_path):
    path = tmp_path / "bad.bin"
    path.write_bytes(b"NOTMAGIC" + b"\x00" * 8)
    with pytest.raises(AssertionError):
        load_weights(path)


def test_f64_downcast(tmp_path):
    path = tmp_path / "w.bin"
    save_weights(path, {"x": np.array([1.5, 2.5], np.float64)})
    back = load_weights(path)
    assert back["x"].dtype == np.float32
